//! The paper's Algorithm 1: streaming authenticated encryption for chopped
//! messages, Tink-style subkey derivation, and the wire header codec.
//!
//! Large messages (≥ 64 KB) are encrypted under a fresh *subkey*
//! `L = AES_K1(V)` for a random 16-byte seed `V`; the message is chopped
//! into segments, segment `i` (1-based) sealed under GCM(L, N_i) with
//! `N_i = [0]_7 ‖ [last]_1 ‖ [i]_4`. The header `(V, m, s)` travels first.
//! Small messages are sealed directly under `K2` with a random 12-byte
//! nonce (key separation — see the module tests for the §IV forgery that
//! breaks the single-key variant).
//!
//! Every segment seal/open here rides the fused one-pass GCM kernels
//! (`Gcm::seal_in_place` / `Gcm::open_in_place`): the zero-copy chopped
//! pipeline — `seal_segment`/`seal_chunk` on the sender,
//! `open_segment`/`open_chunk_into` on the receiver — therefore touches
//! each payload byte exactly once per crypto operation.

use super::gcm::{AuthError, Gcm, NONCE_LEN, TAG_LEN};
use super::rand::secure_array;

/// Messages at or above this size use Algorithm 1 ((k,t)-chopping);
/// smaller ones use direct GCM (paper §IV: "CryptMPI ... uses the
/// (k,t)-chopping algorithm only if the message size is at least 64KB").
pub const CHOP_THRESHOLD: usize = 64 * 1024;

/// Wire opcodes carried in the header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Opcode {
    /// Direct GCM under K2 (small messages).
    Direct = 1,
    /// Algorithm 1 chopped encryption under a subkey of K1.
    Chopped = 2,
    /// Plaintext (Unencrypted baseline / intra-node traffic).
    Plain = 3,
}

impl Opcode {
    fn from_u8(v: u8) -> Option<Self> {
        match v {
            1 => Some(Opcode::Direct),
            2 => Some(Opcode::Chopped),
            3 => Some(Opcode::Plain),
            _ => None,
        }
    }
}

/// Decoded message header.
///
/// Wire layout (fixed 33 bytes, little-endian integers):
/// ```text
/// offset 0   u8   opcode
/// offset 1   [u8;16]  seed V (Chopped) | nonce ‖ 0-pad (Direct) | zero (Plain)
/// offset 17  u64  message length m
/// offset 25  u64  segment size s (Chopped; 0 otherwise)
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Header {
    pub opcode: Opcode,
    pub seed: [u8; 16],
    pub msg_len: u64,
    pub seg_size: u64,
}

/// Encoded header length on the wire.
pub const HEADER_LEN: usize = 33;

impl Header {
    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut out = [0u8; HEADER_LEN];
        out[0] = self.opcode as u8;
        out[1..17].copy_from_slice(&self.seed);
        out[17..25].copy_from_slice(&self.msg_len.to_le_bytes());
        out[25..33].copy_from_slice(&self.seg_size.to_le_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Self, AuthError> {
        if buf.len() < HEADER_LEN {
            return Err(AuthError);
        }
        let opcode = Opcode::from_u8(buf[0]).ok_or(AuthError)?;
        let mut seed = [0u8; 16];
        seed.copy_from_slice(&buf[1..17]);
        let msg_len = u64::from_le_bytes(buf[17..25].try_into().unwrap());
        let seg_size = u64::from_le_bytes(buf[25..33].try_into().unwrap());
        // Structural validation: bytes an opcode leaves unused must be zero
        // on the wire, so malformed headers are rejected before any
        // decryption state is set up. Direct carries a 12-byte nonce with a
        // zero 4-byte pad; Plain carries no seed at all; neither has a
        // segment size.
        let well_formed = match opcode {
            Opcode::Chopped => true,
            Opcode::Direct => seed[NONCE_LEN..].iter().all(|&b| b == 0) && seg_size == 0,
            Opcode::Plain => seed.iter().all(|&b| b == 0) && seg_size == 0,
        };
        if !well_formed {
            return Err(AuthError);
        }
        Ok(Header { opcode, seed, msg_len, seg_size })
    }
}

/// Segment nonce `N_i = [0]_7 ‖ [last]_1 ‖ [i]_4` (paper Algorithm 1, line 9;
/// `i` is 1-based, big-endian).
#[inline]
pub fn segment_nonce(index: u32, last: bool) -> [u8; NONCE_LEN] {
    let mut n = [0u8; NONCE_LEN];
    n[7] = last as u8;
    n[8..12].copy_from_slice(&index.to_be_bytes());
    n
}

/// Derive the Tink-style subkey `L = AES_K(V)` from master context `k1`.
pub fn derive_subkey(k1: &Gcm, seed: &[u8; 16]) -> [u8; 16] {
    let mut l = *seed;
    k1.aes_encrypt_block(&mut l);
    l
}

/// Number of segments implied by a chopped header (receiver side derivation,
/// paper §IV: "it derives the number of segments t ... from the segment size
/// s and the message size m").
pub fn segment_count(msg_len: u64, seg_size: u64) -> Result<u32, AuthError> {
    if seg_size == 0 || msg_len == 0 {
        return Err(AuthError);
    }
    let n = msg_len.div_ceil(seg_size);
    u32::try_from(n).map_err(|_| AuthError)
}

/// Sequential reader over the non-contiguous extents of a source buffer
/// (the lowered iov form of a derived datatype — see `mpi::datatype`).
///
/// `copy_next` hands out the next `dst.len()` *logical* bytes, walking
/// the `(offset, len)` runs in order. This is what lets the gather-seal
/// path copy strided plaintext **directly into the wire buffer** — the
/// one copy the contiguous zero-copy pipeline already pays — instead of
/// packing into an intermediate buffer first and copying again.
pub struct GatherCursor<'a> {
    buf: &'a [u8],
    ext: &'a [(usize, usize)],
    /// Current extent index and byte offset within it.
    idx: usize,
    off: usize,
    remaining: usize,
}

impl<'a> GatherCursor<'a> {
    /// Walk `ext` over `buf`. Every extent must lie inside `buf`.
    pub fn new(buf: &'a [u8], ext: &'a [(usize, usize)]) -> Self {
        let remaining = ext.iter().map(|e| e.1).sum();
        debug_assert!(ext.iter().all(|&(o, l)| o + l <= buf.len()), "extent out of bounds");
        GatherCursor { buf, ext, idx: 0, off: 0, remaining }
    }

    /// Logical bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    /// Copy the next `dst.len()` logical bytes into `dst`.
    /// Panics if fewer remain.
    pub fn copy_next(&mut self, dst: &mut [u8]) {
        assert!(dst.len() <= self.remaining, "gather cursor exhausted");
        let mut at = 0;
        while at < dst.len() {
            let (off, len) = self.ext[self.idx];
            if self.off == len {
                // Zero-length extent (a hand-built iov may contain them;
                // `Datatype::extents` never emits one).
                self.idx += 1;
                self.off = 0;
                continue;
            }
            let take = (len - self.off).min(dst.len() - at);
            dst[at..at + take].copy_from_slice(&self.buf[off + self.off..off + self.off + take]);
            at += take;
            self.off += take;
        }
        self.remaining -= dst.len();
    }

    /// Advance past the next `n` logical bytes without copying them —
    /// positions a fresh cursor at a band's start offset so the parallel
    /// seal engine can hand each worker its own cursor over a disjoint
    /// region of one logical message. Panics if fewer than `n` remain.
    pub fn skip(&mut self, n: usize) {
        assert!(n <= self.remaining, "gather cursor exhausted");
        let mut left = n;
        while left > 0 {
            let (_, len) = self.ext[self.idx];
            if self.off == len {
                self.idx += 1;
                self.off = 0;
                continue;
            }
            let take = (len - self.off).min(left);
            left -= take;
            self.off += take;
        }
        self.remaining -= n;
    }

    /// Append the next `n` logical bytes to `out` — the push-style mirror
    /// of [`copy_next`](Self::copy_next) for paths that build a `Vec`
    /// frame incrementally (no dead zero-fill of the body region).
    /// Panics if fewer than `n` bytes remain.
    pub fn append_to(&mut self, out: &mut Vec<u8>, n: usize) {
        assert!(n <= self.remaining, "gather cursor exhausted");
        let mut left = n;
        while left > 0 {
            let (off, len) = self.ext[self.idx];
            if self.off == len {
                self.idx += 1;
                self.off = 0;
                continue;
            }
            let take = (len - self.off).min(left);
            out.extend_from_slice(&self.buf[off + self.off..off + self.off + take]);
            left -= take;
            self.off += take;
        }
        self.remaining -= n;
    }
}

/// Sequential writer over the non-contiguous extents of a destination
/// buffer — the receive-side mirror of [`GatherCursor`]. `copy_next`
/// scatters the next `src.len()` logical bytes out to their strided
/// positions; the open-scatter path calls it only with plaintext whose
/// tag already verified, so unauthenticated bytes never reach the user
/// buffer.
pub struct ScatterCursor<'a> {
    buf: &'a mut [u8],
    ext: &'a [(usize, usize)],
    idx: usize,
    off: usize,
    remaining: usize,
}

impl<'a> ScatterCursor<'a> {
    /// Walk `ext` over `buf`. Extents must lie inside `buf`; for a
    /// well-defined scatter they must also be disjoint and in increasing
    /// order (`Datatype::is_monotonic_disjoint`), which the coordinator
    /// validates before building a cursor.
    pub fn new(buf: &'a mut [u8], ext: &'a [(usize, usize)]) -> Self {
        let remaining = ext.iter().map(|e| e.1).sum();
        debug_assert!(ext.iter().all(|&(o, l)| o + l <= buf.len()), "extent out of bounds");
        ScatterCursor { buf, ext, idx: 0, off: 0, remaining }
    }

    /// Logical bytes of destination capacity not yet written.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    /// Scatter the next `src.len()` logical bytes from `src`.
    /// Panics if less capacity remains.
    pub fn copy_next(&mut self, src: &[u8]) {
        assert!(src.len() <= self.remaining, "scatter cursor exhausted");
        let mut at = 0;
        while at < src.len() {
            let (off, len) = self.ext[self.idx];
            if self.off == len {
                // Zero-length extent — see `GatherCursor::copy_next`.
                self.idx += 1;
                self.off = 0;
                continue;
            }
            let take = (len - self.off).min(src.len() - at);
            self.buf[off + self.off..off + self.off + take].copy_from_slice(&src[at..at + take]);
            at += take;
            self.off += take;
        }
        self.remaining -= src.len();
    }

    /// Advance past the next `n` logical bytes of destination capacity
    /// without writing — the scatter mirror of [`GatherCursor::skip`].
    /// Panics if less capacity remains.
    pub fn skip(&mut self, n: usize) {
        assert!(n <= self.remaining, "scatter cursor exhausted");
        let mut left = n;
        while left > 0 {
            let (_, len) = self.ext[self.idx];
            if self.off == len {
                self.idx += 1;
                self.off = 0;
                continue;
            }
            let take = (len - self.off).min(left);
            left -= take;
            self.off += take;
        }
        self.remaining -= n;
    }
}

/// Sender-side state for one chopped message: knows the subkey and hands out
/// per-segment seals. Segments may be sealed from multiple worker threads
/// (the context is `Sync`; each seal only needs the immutable subkey).
pub struct StreamSealer {
    sub: Gcm,
    header: Header,
    nsegs: u32,
}

impl StreamSealer {
    /// Start a chopped encryption of an `msg_len`-byte message split into
    /// `nsegs` segments under master key context `k1`. Draws a fresh random
    /// seed. `nsegs` is `k·t` from the (k,t)-chopping algorithm.
    pub fn new(k1: &Gcm, msg_len: usize, nsegs: u32) -> Self {
        assert!(msg_len > 0 && nsegs > 0, "empty chopped message");
        let seed: [u8; 16] = secure_array();
        Self::with_seed(k1, msg_len, nsegs, seed)
    }

    /// Deterministic-seed variant (tests; also the §IV forgery demo).
    pub fn with_seed(k1: &Gcm, msg_len: usize, nsegs: u32, seed: [u8; 16]) -> Self {
        let seg_size = (msg_len as u64).div_ceil(nsegs as u64);
        // Recompute the actual segment count: ceil division can make the
        // final segments empty for adversarial (m, nsegs) combinations;
        // the receiver derives count from (m, s), so the sender must too.
        let nsegs = segment_count(msg_len as u64, seg_size).expect("nonempty");
        // Subkey setup is per-message: inherit the parent's backend choice
        // (no env lookup, no CPU re-detection) and let the GHASH power
        // schedule build lazily on the first ≥128-byte segment.
        let sub = Gcm::subkey_like(k1, &derive_subkey(k1, &seed));
        let header =
            Header { opcode: Opcode::Chopped, seed, msg_len: msg_len as u64, seg_size };
        StreamSealer { sub, header, nsegs }
    }

    pub fn header(&self) -> &Header {
        &self.header
    }

    pub fn num_segments(&self) -> u32 {
        self.nsegs
    }

    /// Number of `t`-segment chunks this stream travels as: `⌈nsegs/t⌉`.
    /// Both sides of a chopped transfer derive the same value (the chunk
    /// grouping is part of the wire protocol), and the pipeline stats and
    /// tracing lanes key off it.
    pub fn num_chunks(&self, t: u32) -> usize {
        self.nsegs.div_ceil(t.max(1)) as usize
    }

    pub fn segment_size(&self) -> usize {
        self.header.seg_size as usize
    }

    /// Byte range of segment `index` (1-based) within the message.
    pub fn segment_range(&self, index: u32) -> std::ops::Range<usize> {
        let s = self.header.seg_size as usize;
        let start = s * (index as usize - 1);
        let end = (start + s).min(self.header.msg_len as usize);
        start..end
    }

    /// Seal segment `index` (1-based) in place; returns the tag.
    pub fn seal_segment(&self, index: u32, data: &mut [u8]) -> [u8; TAG_LEN] {
        debug_assert!(index >= 1 && index <= self.nsegs);
        let nonce = segment_nonce(index, index == self.nsegs);
        self.sub.seal_in_place(&nonce, &[], data)
    }

    /// Fused gather-seal of segment `index` (1-based): gather the
    /// segment's plaintext from the source cursor straight into its wire
    /// slot `body`, then run the one-pass seal kernel in place there.
    /// No intermediate pack buffer exists — the gather *is* the
    /// plaintext→wire copy the contiguous pipeline already performs, so a
    /// strided payload costs exactly the same passes as a contiguous one.
    pub fn seal_segment_gather(
        &self,
        index: u32,
        src: &mut GatherCursor,
        body: &mut [u8],
    ) -> [u8; TAG_LEN] {
        debug_assert_eq!(body.len(), self.segment_range(index).len());
        src.copy_next(body);
        self.seal_segment(index, body)
    }

    /// Wire length of the contiguous chunk covering segments `a..=b`
    /// (1-based, inclusive): the segment bodies followed by the trailing
    /// tag block, `body_a ‖ … ‖ body_b ‖ tag_a ‖ … ‖ tag_b`.
    pub fn chunk_wire_len(&self, a: u32, b: u32) -> usize {
        debug_assert!(a >= 1 && a <= b && b <= self.nsegs);
        let bodies = self.segment_range(b).end - self.segment_range(a).start;
        bodies + (b - a + 1) as usize * TAG_LEN
    }

    /// Seal segments `a..=b` in place over one contiguous wire buffer in
    /// the [`chunk_wire_len`](Self::chunk_wire_len) layout. On entry the
    /// body region holds plaintext; on return it holds ciphertext and the
    /// tag region is filled. This is the sequential reference path — the
    /// coordinator runs the identical layout through the worker pool over
    /// disjoint slices of the same buffer.
    pub fn seal_chunk(&self, a: u32, b: u32, wire: &mut [u8]) {
        assert_eq!(wire.len(), self.chunk_wire_len(a, b), "wire buffer size");
        let nparts = (b - a + 1) as usize;
        let bodies_len = wire.len() - nparts * TAG_LEN;
        let (bodies, tags) = wire.split_at_mut(bodies_len);
        let mut bodies = bodies;
        for (j, i) in (a..=b).enumerate() {
            let len = self.segment_range(i).len();
            let (body, rest) = std::mem::take(&mut bodies).split_at_mut(len);
            bodies = rest;
            let tag = self.seal_segment(i, body);
            tags[j * TAG_LEN..(j + 1) * TAG_LEN].copy_from_slice(&tag);
        }
    }

    /// Gather-seal segments `a..=b` over one contiguous wire buffer in
    /// the [`chunk_wire_len`](Self::chunk_wire_len) layout, drawing the
    /// plaintext from `src`'s extents. The strided counterpart of
    /// [`seal_chunk`](Self::seal_chunk): segment-by-segment, each body is
    /// gathered into its wire slot and sealed while still hot — one sweep,
    /// zero pack buffer.
    pub fn seal_chunk_gather(&self, a: u32, b: u32, src: &mut GatherCursor, wire: &mut [u8]) {
        assert_eq!(wire.len(), self.chunk_wire_len(a, b), "wire buffer size");
        let nparts = (b - a + 1) as usize;
        let bodies_len = wire.len() - nparts * TAG_LEN;
        let (bodies, tags) = wire.split_at_mut(bodies_len);
        let mut bodies = bodies;
        for (j, i) in (a..=b).enumerate() {
            let len = self.segment_range(i).len();
            let (body, rest) = std::mem::take(&mut bodies).split_at_mut(len);
            bodies = rest;
            let tag = self.seal_segment_gather(i, src, body);
            tags[j * TAG_LEN..(j + 1) * TAG_LEN].copy_from_slice(&tag);
        }
    }
}

/// Receiver-side state for one chopped message. Enforces the streaming-AE
/// discipline: segments must verify under their positional nonce, the count
/// must match the header, and the last-flag must appear exactly at the end.
pub struct StreamOpener {
    sub: Gcm,
    msg_len: u64,
    seg_size: u64,
    nsegs: u32,
    received: u32,
}

impl StreamOpener {
    /// Initialize from a decoded chopped header under master context `k1`.
    pub fn new(k1: &Gcm, header: &Header) -> Result<Self, AuthError> {
        if header.opcode != Opcode::Chopped {
            return Err(AuthError);
        }
        let nsegs = segment_count(header.msg_len, header.seg_size)?;
        // Same cheap per-message subkey construction as the sealer.
        let sub = Gcm::subkey_like(k1, &derive_subkey(k1, &header.seed));
        Ok(StreamOpener {
            sub,
            msg_len: header.msg_len,
            seg_size: header.seg_size,
            nsegs,
            received: 0,
        })
    }

    pub fn num_segments(&self) -> u32 {
        self.nsegs
    }

    /// Number of `t`-segment chunks the stream carries — the opener-side
    /// mirror of [`StreamSealer::num_chunks`].
    pub fn num_chunks(&self, t: u32) -> usize {
        self.nsegs.div_ceil(t.max(1)) as usize
    }

    /// Expected ciphertext length of segment `index` (1-based), tag excluded.
    pub fn segment_len(&self, index: u32) -> usize {
        let start = self.seg_size * (index as u64 - 1);
        let end = (start + self.seg_size).min(self.msg_len);
        (end - start) as usize
    }

    /// Byte range of segment `index` within the plaintext message.
    pub fn segment_range(&self, index: u32) -> std::ops::Range<usize> {
        let start = (self.seg_size * (index as u64 - 1)) as usize;
        start..start + self.segment_len(index)
    }

    /// Verify-and-decrypt segment `index` (1-based) in place.
    ///
    /// Stateless per segment (may be called from worker threads in any
    /// order); call [`finish`](Self::finish) after all segments to enforce
    /// the count. A segment with the wrong position, wrong last-flag, or any
    /// tamper fails because the nonce (and hence the tag) binds position.
    pub fn open_segment(
        &self,
        index: u32,
        data: &mut [u8],
        tag: &[u8; TAG_LEN],
    ) -> Result<(), AuthError> {
        if index == 0 || index > self.nsegs || data.len() != self.segment_len(index) {
            return Err(AuthError);
        }
        let nonce = segment_nonce(index, index == self.nsegs);
        self.sub.open_in_place(&nonce, &[], data, tag)
    }

    /// Verify-and-decrypt segments `a..=b` of a contiguous wire chunk
    /// (`body_a ‖ … ‖ body_b ‖ tag_a ‖ … ‖ tag_b`) into `out`, which must
    /// be exactly the plaintext region of those segments. Zero-copy: the
    /// ciphertext bodies are copied once — directly into their final
    /// position in `out` — and decrypted in place there. Marks every
    /// successfully opened segment as received.
    pub fn open_chunk_into(
        &mut self,
        a: u32,
        b: u32,
        wire: &[u8],
        out: &mut [u8],
    ) -> Result<(), AuthError> {
        if a == 0 || a > b || b > self.nsegs {
            return Err(AuthError);
        }
        let nparts = (b - a + 1) as usize;
        let bodies_len: usize = (a..=b).map(|i| self.segment_len(i)).sum();
        if wire.len() != bodies_len + nparts * TAG_LEN || out.len() != bodies_len {
            return Err(AuthError);
        }
        out.copy_from_slice(&wire[..bodies_len]);
        let tags = &wire[bodies_len..];
        let mut rest: &mut [u8] = out;
        for (j, i) in (a..=b).enumerate() {
            let len = self.segment_len(i);
            let (body, tail) = std::mem::take(&mut rest).split_at_mut(len);
            rest = tail;
            let tag: [u8; TAG_LEN] = tags[j * TAG_LEN..(j + 1) * TAG_LEN].try_into().unwrap();
            self.open_segment(i, body, &tag)?;
            self.mark_received();
        }
        Ok(())
    }

    /// Verify-and-decrypt segments `a..=b` of a contiguous wire chunk
    /// (`body_a ‖ … ‖ body_b ‖ tag_a ‖ … ‖ tag_b`), scattering the
    /// plaintext out through `out`'s extents — the fused open-scatter
    /// mirror of the gather-seal path. Decryption runs **in place in the
    /// wire buffer** (which is consumed scratch anyway), so the scatter
    /// copy is the only data movement besides the one crypto sweep: no
    /// intermediate contiguous plaintext buffer exists. Each segment is
    /// scattered only after its own tag verified; on error, segments
    /// before the failure have already been delivered (the caller treats
    /// the whole receive as failed, as MPI would).
    pub fn open_chunk_scatter(
        &mut self,
        a: u32,
        b: u32,
        wire: &mut [u8],
        out: &mut ScatterCursor,
    ) -> Result<(), AuthError> {
        if a == 0 || a > b || b > self.nsegs {
            return Err(AuthError);
        }
        let nparts = (b - a + 1) as usize;
        let bodies_len: usize = (a..=b).map(|i| self.segment_len(i)).sum();
        if wire.len() != bodies_len + nparts * TAG_LEN || out.remaining() < bodies_len {
            return Err(AuthError);
        }
        let (bodies, tags) = wire.split_at_mut(bodies_len);
        let mut rest: &mut [u8] = bodies;
        for (j, i) in (a..=b).enumerate() {
            let len = self.segment_len(i);
            let (body, tail) = std::mem::take(&mut rest).split_at_mut(len);
            rest = tail;
            let tag: [u8; TAG_LEN] = tags[j * TAG_LEN..(j + 1) * TAG_LEN].try_into().unwrap();
            self.open_segment(i, body, &tag)?;
            out.copy_next(body);
            self.mark_received();
        }
        Ok(())
    }

    /// Record one successfully opened segment.
    pub fn mark_received(&mut self) {
        self.received += 1;
    }

    /// Final count check (paper: "if the receiver does not get the correct
    /// number of ciphertext segments, it will report a decryption failure").
    pub fn finish(&self) -> Result<(), AuthError> {
        if self.received == self.nsegs {
            Ok(())
        } else {
            Err(AuthError)
        }
    }
}

/// One-shot convenience: chop `msg` into `nsegs` segments and encrypt
/// (header, segments with trailing tags). This is the legacy O(segments)-
/// allocation path, kept as the correctness reference and the "before"
/// side of the zero-copy benchmarks; the coordinator hot path uses the
/// contiguous wire layout ([`chop_encrypt_into`] / [`StreamSealer::seal_chunk`]).
pub fn chop_encrypt(k1: &Gcm, msg: &[u8], nsegs: u32) -> (Header, Vec<Vec<u8>>) {
    let sealer = StreamSealer::new(k1, msg.len(), nsegs);
    let mut segs = Vec::with_capacity(sealer.num_segments() as usize);
    for i in 1..=sealer.num_segments() {
        let mut buf = msg[sealer.segment_range(i)].to_vec();
        let tag = sealer.seal_segment(i, &mut buf);
        buf.extend_from_slice(&tag);
        segs.push(buf);
    }
    (sealer.header().clone(), segs)
}

/// One-shot zero-copy encrypt: chop `msg` into `nsegs` segments and write
/// the single contiguous wire image `bodies ‖ tags` into `wire` (resized in
/// place, reusing its allocation). Returns the header. With a recycled
/// `wire` buffer this allocates O(1) buffers per message, vs the
/// O(segments) `Vec`s of [`chop_encrypt`].
pub fn chop_encrypt_into(k1: &Gcm, msg: &[u8], nsegs: u32, wire: &mut Vec<u8>) -> Header {
    let sealer = StreamSealer::new(k1, msg.len(), nsegs);
    chop_seal_into(&sealer, msg, wire)
}

/// Deterministic-seed variant of [`chop_encrypt_into`] — the anchor of the
/// parallel-vs-serial wire-image equivalence battery (same seed ⇒ the
/// wire must be byte-identical however the sealing was scheduled).
pub fn chop_encrypt_into_seeded(
    k1: &Gcm,
    msg: &[u8],
    nsegs: u32,
    seed: [u8; 16],
    wire: &mut Vec<u8>,
) -> Header {
    let sealer = StreamSealer::with_seed(k1, msg.len(), nsegs, seed);
    chop_seal_into(&sealer, msg, wire)
}

fn chop_seal_into(sealer: &StreamSealer, msg: &[u8], wire: &mut Vec<u8>) -> Header {
    let n = sealer.num_segments();
    resize_wire(wire, sealer.chunk_wire_len(1, n));
    wire[..msg.len()].copy_from_slice(msg);
    sealer.seal_chunk(1, n, &mut wire[..]);
    sealer.header().clone()
}

/// Resize a recycled wire buffer without clearing: every byte is
/// overwritten by the copy/gather + seal that follows, so only a grown
/// tail ever needs initializing.
fn resize_wire(wire: &mut Vec<u8>, total: usize) {
    if wire.len() > total {
        wire.truncate(total);
    } else {
        wire.resize(total, 0);
    }
}

/// One-shot decrypt of [`chop_encrypt_into`]'s contiguous wire layout.
pub fn chop_decrypt_wire(k1: &Gcm, header: &Header, wire: &[u8]) -> Result<Vec<u8>, AuthError> {
    let mut opener = StreamOpener::new(k1, header)?;
    let n = opener.num_segments();
    // Bound the claimed length by the actual wire bytes BEFORE allocating:
    // the header is unauthenticated, so a forged msg_len must produce a
    // clean failure, not an absurd allocation. u128 math — no overflow.
    let expect = header.msg_len as u128 + n as u128 * TAG_LEN as u128;
    if wire.len() as u128 != expect {
        return Err(AuthError);
    }
    let mut out = vec![0u8; header.msg_len as usize];
    opener.open_chunk_into(1, n, wire, &mut out)?;
    opener.finish()?;
    Ok(out)
}

/// One-shot fused gather-seal: chop the strided message selected by `ext`
/// over `src` into `nsegs` segments and write the contiguous wire image
/// `bodies ‖ tags` into `wire` (resized in place, reusing its
/// allocation). The wire image is byte-identical to what
/// [`chop_encrypt_into`] produces for the packed equivalent under the
/// same seed — receivers cannot tell a gathered message from a packed
/// one — but no pack buffer and no second plaintext pass ever exist.
pub fn chop_encrypt_gather_into(
    k1: &Gcm,
    src: &[u8],
    ext: &[(usize, usize)],
    nsegs: u32,
    wire: &mut Vec<u8>,
) -> Header {
    let msg_len: usize = ext.iter().map(|e| e.1).sum();
    let sealer = StreamSealer::new(k1, msg_len, nsegs);
    chop_seal_gather_into(&sealer, src, ext, wire)
}

/// Deterministic-seed variant of [`chop_encrypt_gather_into`] (the
/// gather-seal leg of the wire-image equivalence battery).
pub fn chop_encrypt_gather_into_seeded(
    k1: &Gcm,
    src: &[u8],
    ext: &[(usize, usize)],
    nsegs: u32,
    seed: [u8; 16],
    wire: &mut Vec<u8>,
) -> Header {
    let msg_len: usize = ext.iter().map(|e| e.1).sum();
    let sealer = StreamSealer::with_seed(k1, msg_len, nsegs, seed);
    chop_seal_gather_into(&sealer, src, ext, wire)
}

fn chop_seal_gather_into(
    sealer: &StreamSealer,
    src: &[u8],
    ext: &[(usize, usize)],
    wire: &mut Vec<u8>,
) -> Header {
    let n = sealer.num_segments();
    resize_wire(wire, sealer.chunk_wire_len(1, n));
    let mut cur = GatherCursor::new(src, ext);
    sealer.seal_chunk_gather(1, n, &mut cur, &mut wire[..]);
    sealer.header().clone()
}

/// One-shot fused open-scatter of the contiguous wire layout: decrypt in
/// place in `wire` and scatter the plaintext out to `ext` over `dst`.
/// The receive-side mirror of [`chop_encrypt_gather_into`].
pub fn chop_decrypt_wire_scatter(
    k1: &Gcm,
    header: &Header,
    wire: &mut [u8],
    dst: &mut [u8],
    ext: &[(usize, usize)],
) -> Result<(), AuthError> {
    let mut opener = StreamOpener::new(k1, header)?;
    let n = opener.num_segments();
    let cap: usize = ext.iter().map(|e| e.1).sum();
    let expect = header.msg_len as u128 + n as u128 * TAG_LEN as u128;
    if wire.len() as u128 != expect || (header.msg_len as u128) > cap as u128 {
        return Err(AuthError);
    }
    let mut cur = ScatterCursor::new(dst, ext);
    opener.open_chunk_scatter(1, n, wire, &mut cur)?;
    opener.finish()
}

/// One-shot convenience: decrypt a full chopped message.
pub fn chop_decrypt(k1: &Gcm, header: &Header, segs: &[Vec<u8>]) -> Result<Vec<u8>, AuthError> {
    let mut opener = StreamOpener::new(k1, header)?;
    if segs.len() != opener.num_segments() as usize {
        return Err(AuthError);
    }
    // Bound the claimed length by the bytes actually provided before
    // allocating (the header is unauthenticated; see chop_decrypt_wire).
    let provided: u128 = segs.iter().map(|s| s.len() as u128).sum();
    let expect = header.msg_len as u128 + segs.len() as u128 * TAG_LEN as u128;
    if provided != expect {
        return Err(AuthError);
    }
    let mut out = vec![0u8; header.msg_len as usize];
    for (i, seg) in segs.iter().enumerate() {
        let index = i as u32 + 1;
        let body_len = opener.segment_len(index);
        if seg.len() != body_len + TAG_LEN {
            return Err(AuthError);
        }
        let mut body = seg[..body_len].to_vec();
        let tag: [u8; TAG_LEN] = seg[body_len..].try_into().unwrap();
        opener.open_segment(index, &mut body, &tag)?;
        out[opener.segment_range(index)].copy_from_slice(&body);
        opener.mark_received();
    }
    opener.finish()?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Parallel seal/open engine (DESIGN.md §12)
//
// Every segment owns its positional nonce and a disjoint wire slice, so
// the one-shot forms below fan segments across a `WorkerPool` in
// contiguous *bands* (one job per worker, each sealing/opening its
// segments in sequence). Chunk content depends only on (seed, msg_len,
// nsegs, index) — never on scheduling — so the wire image is
// byte-identical to the serial forms under the same seed. On open, a
// shutdown flag latches the first AuthError: the remaining workers drain
// (skipping their leftover segments) and the caller surfaces the same
// clean `AuthError` the serial path produces.
// ---------------------------------------------------------------------------

use crate::coordinator::pool::WorkerPool;
use std::sync::atomic::{AtomicBool, Ordering};

/// Split segments `1..=n` into at most `w` contiguous, near-equal bands
/// (earlier bands take the remainder). Always at least one band.
fn band_ranges(n: u32, w: usize) -> Vec<(u32, u32)> {
    let w = w.clamp(1, n.max(1) as usize) as u32;
    let base = n / w;
    let extra = n % w;
    let mut out = Vec::with_capacity(w as usize);
    let mut a = 1u32;
    for i in 0..w {
        let len = base + u32::from(i < extra);
        out.push((a, a + len - 1));
        a += len;
    }
    out
}

/// Seal segments `a..=b` over split body/tag regions (the band form of
/// [`StreamSealer::seal_chunk`], where a band's bodies and tags are two
/// disjoint slices of one larger wire buffer rather than adjacent).
fn seal_band(sealer: &StreamSealer, a: u32, b: u32, bodies: &mut [u8], tags: &mut [u8]) {
    let mut bodies = bodies;
    for (j, i) in (a..=b).enumerate() {
        let len = sealer.segment_range(i).len();
        let (body, rest) = std::mem::take(&mut bodies).split_at_mut(len);
        bodies = rest;
        let tag = sealer.seal_segment(i, body);
        tags[j * TAG_LEN..(j + 1) * TAG_LEN].copy_from_slice(&tag);
    }
}

/// Seal the full `bodies ‖ tags` wire image across the pool's workers.
/// The body region must already hold plaintext.
fn seal_wire_parallel(sealer: &StreamSealer, wire: &mut [u8], pool: &WorkerPool) {
    let n = sealer.num_segments();
    let bands = band_ranges(n, pool.size());
    if bands.len() <= 1 {
        return sealer.seal_chunk(1, n, wire);
    }
    let bodies_len = wire.len() - n as usize * TAG_LEN;
    let (mut bodies, mut tags) = wire.split_at_mut(bodies_len);
    let mut jobs = Vec::with_capacity(bands.len());
    for &(a, b) in &bands {
        let blen = sealer.segment_range(b).end - sealer.segment_range(a).start;
        let (band_bodies, rest) = std::mem::take(&mut bodies).split_at_mut(blen);
        bodies = rest;
        let (band_tags, rest) =
            std::mem::take(&mut tags).split_at_mut((b - a + 1) as usize * TAG_LEN);
        tags = rest;
        jobs.push(move || seal_band(sealer, a, b, band_bodies, band_tags));
    }
    pool.scope_run(jobs);
}

/// Parallel form of [`chop_encrypt_into`]: same wire image, same header,
/// the sealing fanned across `pool`'s workers in contiguous bands.
pub fn chop_encrypt_into_parallel(
    k1: &Gcm,
    msg: &[u8],
    nsegs: u32,
    wire: &mut Vec<u8>,
    pool: &WorkerPool,
) -> Header {
    chop_encrypt_into_parallel_seeded(k1, msg, nsegs, secure_array(), wire, pool)
}

/// Deterministic-seed variant of [`chop_encrypt_into_parallel`].
pub fn chop_encrypt_into_parallel_seeded(
    k1: &Gcm,
    msg: &[u8],
    nsegs: u32,
    seed: [u8; 16],
    wire: &mut Vec<u8>,
    pool: &WorkerPool,
) -> Header {
    let sealer = StreamSealer::with_seed(k1, msg.len(), nsegs, seed);
    let n = sealer.num_segments();
    resize_wire(wire, sealer.chunk_wire_len(1, n));
    wire[..msg.len()].copy_from_slice(msg);
    seal_wire_parallel(&sealer, &mut wire[..], pool);
    sealer.header().clone()
}

/// Parallel form of [`chop_encrypt_gather_into`]: each band job walks its
/// own [`GatherCursor`], skipped to the band's logical offset, so the
/// strided gather fans out with the sealing.
pub fn chop_encrypt_gather_into_parallel(
    k1: &Gcm,
    src: &[u8],
    ext: &[(usize, usize)],
    nsegs: u32,
    wire: &mut Vec<u8>,
    pool: &WorkerPool,
) -> Header {
    chop_encrypt_gather_into_parallel_seeded(k1, src, ext, nsegs, secure_array(), wire, pool)
}

/// Deterministic-seed variant of [`chop_encrypt_gather_into_parallel`].
pub fn chop_encrypt_gather_into_parallel_seeded(
    k1: &Gcm,
    src: &[u8],
    ext: &[(usize, usize)],
    nsegs: u32,
    seed: [u8; 16],
    wire: &mut Vec<u8>,
    pool: &WorkerPool,
) -> Header {
    let msg_len: usize = ext.iter().map(|e| e.1).sum();
    let sealer = StreamSealer::with_seed(k1, msg_len, nsegs, seed);
    let n = sealer.num_segments();
    resize_wire(wire, sealer.chunk_wire_len(1, n));
    let bands = band_ranges(n, pool.size());
    if bands.len() <= 1 {
        let mut cur = GatherCursor::new(src, ext);
        sealer.seal_chunk_gather(1, n, &mut cur, &mut wire[..]);
        return sealer.header().clone();
    }
    let bodies_len = msg_len;
    let (mut bodies, mut tags) = wire.split_at_mut(bodies_len);
    let sealer_ref = &sealer;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(bands.len());
    for &(a, b) in &bands {
        let start = sealer.segment_range(a).start;
        let blen = sealer.segment_range(b).end - start;
        let (band_bodies, rest) = std::mem::take(&mut bodies).split_at_mut(blen);
        bodies = rest;
        let (band_tags, rest) =
            std::mem::take(&mut tags).split_at_mut((b - a + 1) as usize * TAG_LEN);
        tags = rest;
        jobs.push(Box::new(move || {
            let mut cur = GatherCursor::new(src, ext);
            cur.skip(start);
            let mut bodies = band_bodies;
            for (j, i) in (a..=b).enumerate() {
                let len = sealer_ref.segment_range(i).len();
                let (body, rest) = std::mem::take(&mut bodies).split_at_mut(len);
                bodies = rest;
                let tag = sealer_ref.seal_segment_gather(i, &mut cur, body);
                band_tags[j * TAG_LEN..(j + 1) * TAG_LEN].copy_from_slice(&tag);
            }
        }));
    }
    pool.scope_run(jobs);
    sealer.header().clone()
}

/// Verify-and-decrypt segments `a..=b` in place over split body/tag
/// regions, with the shutdown-flag error latch: the first failed tag sets
/// `failed` and every band (this one and the others, at their next
/// segment boundary) stops doing work and drains. The failed segment's
/// ciphertext is restored by GCM's restore-on-reject; segments never
/// reached stay untouched ciphertext. Crate-visible: the rank's parallel
/// receive path fans whole chunks over this same primitive.
pub(crate) fn open_band(
    opener: &StreamOpener,
    a: u32,
    b: u32,
    bodies: &mut [u8],
    tags: &[u8],
    failed: &AtomicBool,
) {
    let mut bodies = bodies;
    for (j, i) in (a..=b).enumerate() {
        if failed.load(Ordering::Relaxed) {
            return;
        }
        let len = opener.segment_len(i);
        let (body, rest) = std::mem::take(&mut bodies).split_at_mut(len);
        bodies = rest;
        let tag: [u8; TAG_LEN] = tags[j * TAG_LEN..(j + 1) * TAG_LEN].try_into().unwrap();
        if opener.open_segment(i, body, &tag).is_err() {
            failed.store(true, Ordering::Relaxed);
            return;
        }
    }
}

/// Parallel form of [`chop_decrypt_wire`]: ciphertext bodies are copied
/// once into the output buffer and decrypted in place there by band jobs.
/// On any tamper the error latches and `wire` (never written) plus the
/// same clean [`AuthError`] as the serial path are all the caller sees.
pub fn chop_decrypt_wire_parallel(
    k1: &Gcm,
    header: &Header,
    wire: &[u8],
    pool: &WorkerPool,
) -> Result<Vec<u8>, AuthError> {
    let mut opener = StreamOpener::new(k1, header)?;
    let n = opener.num_segments();
    // Same unauthenticated-header length bound as the serial path.
    let expect = header.msg_len as u128 + n as u128 * TAG_LEN as u128;
    if wire.len() as u128 != expect {
        return Err(AuthError);
    }
    let bands = band_ranges(n, pool.size());
    let mut out = vec![0u8; header.msg_len as usize];
    if bands.len() <= 1 {
        opener.open_chunk_into(1, n, wire, &mut out)?;
        opener.finish()?;
        return Ok(out);
    }
    let bodies_len = header.msg_len as usize;
    out.copy_from_slice(&wire[..bodies_len]);
    let failed = AtomicBool::new(false);
    {
        let opener_ref = &opener;
        let failed_ref = &failed;
        let mut out_rest: &mut [u8] = &mut out;
        let mut tags_rest = &wire[bodies_len..];
        let mut jobs = Vec::with_capacity(bands.len());
        for &(a, b) in &bands {
            let blen: usize = (a..=b).map(|i| opener_ref.segment_len(i)).sum();
            let (band_out, rest) = std::mem::take(&mut out_rest).split_at_mut(blen);
            out_rest = rest;
            let (band_tags, rest) = tags_rest.split_at((b - a + 1) as usize * TAG_LEN);
            tags_rest = rest;
            jobs.push(move || open_band(opener_ref, a, b, band_out, band_tags, failed_ref));
        }
        pool.scope_run(jobs);
    }
    if failed.load(Ordering::Relaxed) {
        return Err(AuthError);
    }
    for _ in 0..n {
        opener.mark_received();
    }
    opener.finish()?;
    Ok(out)
}

/// Parallel form of [`chop_decrypt_wire_scatter`]: band jobs decrypt in
/// place in `wire`, then — only once **every** tag verified — one scatter
/// sweep delivers the plaintext through `ext`. Stricter than the serial
/// path (which scatters segment-by-segment as each verifies): under
/// parallel open, nothing reaches the user buffer on a failed message.
pub fn chop_decrypt_wire_scatter_parallel(
    k1: &Gcm,
    header: &Header,
    wire: &mut [u8],
    dst: &mut [u8],
    ext: &[(usize, usize)],
    pool: &WorkerPool,
) -> Result<(), AuthError> {
    let mut opener = StreamOpener::new(k1, header)?;
    let n = opener.num_segments();
    let cap: usize = ext.iter().map(|e| e.1).sum();
    let expect = header.msg_len as u128 + n as u128 * TAG_LEN as u128;
    if wire.len() as u128 != expect || (header.msg_len as u128) > cap as u128 {
        return Err(AuthError);
    }
    let bands = band_ranges(n, pool.size());
    if bands.len() <= 1 {
        let mut cur = ScatterCursor::new(dst, ext);
        opener.open_chunk_scatter(1, n, wire, &mut cur)?;
        return opener.finish();
    }
    let bodies_len = header.msg_len as usize;
    let (bodies, tags) = wire.split_at_mut(bodies_len);
    let failed = AtomicBool::new(false);
    {
        let opener_ref = &opener;
        let failed_ref = &failed;
        let mut bodies_rest: &mut [u8] = bodies;
        let mut tags_rest: &[u8] = tags;
        let mut jobs = Vec::with_capacity(bands.len());
        for &(a, b) in &bands {
            let blen: usize = (a..=b).map(|i| opener_ref.segment_len(i)).sum();
            let (band_bodies, rest) = std::mem::take(&mut bodies_rest).split_at_mut(blen);
            bodies_rest = rest;
            let (band_tags, rest) = tags_rest.split_at((b - a + 1) as usize * TAG_LEN);
            tags_rest = rest;
            jobs.push(move || open_band(opener_ref, a, b, band_bodies, band_tags, failed_ref));
        }
        pool.scope_run(jobs);
    }
    if failed.load(Ordering::Relaxed) {
        return Err(AuthError);
    }
    let mut cur = ScatterCursor::new(dst, ext);
    cur.copy_next(bodies);
    for _ in 0..n {
        opener.mark_received();
    }
    opener.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rand::SimRng;

    fn msg(n: usize, seed: u64) -> Vec<u8> {
        let mut r = SimRng::new(seed);
        let mut m = vec![0u8; n];
        r.fill(&mut m);
        m
    }

    #[test]
    fn header_roundtrip() {
        let h = Header {
            opcode: Opcode::Chopped,
            seed: [0xabu8; 16],
            msg_len: 1 << 22,
            seg_size: 65536,
        };
        assert_eq!(Header::decode(&h.encode()).unwrap(), h);
        assert!(Header::decode(&[0u8; 5]).is_err());
        let mut bad = h.encode();
        bad[0] = 77; // unknown opcode
        assert!(Header::decode(&bad).is_err());
    }

    #[test]
    fn chop_roundtrip_various_shapes() {
        let k1 = Gcm::new(&[1u8; 16]);
        for (len, nsegs) in
            [(1usize, 1u32), (100, 1), (100, 3), (65536, 8), (65537, 8), (1 << 20, 64), (17, 17), (5, 16)]
        {
            let m = msg(len, len as u64);
            let (h, segs) = chop_encrypt(&k1, &m, nsegs);
            let out = chop_decrypt(&k1, &h, &segs).expect("roundtrip");
            assert_eq!(out, m, "len={len} nsegs={nsegs}");
        }
    }

    #[test]
    fn num_chunks_matches_both_sides() {
        let k1 = Gcm::new(&[7u8; 16]);
        for (len, nsegs, t) in
            [(100usize, 3u32, 1u32), (65536, 8, 4), (65537, 8, 3), (1 << 20, 64, 16), (17, 17, 5)]
        {
            let sealer = StreamSealer::new(&k1, len, nsegs);
            let opener = StreamOpener::new(&k1, sealer.header()).unwrap();
            let want = sealer.num_segments().div_ceil(t) as usize;
            assert_eq!(sealer.num_chunks(t), want, "len={len} nsegs={nsegs} t={t}");
            assert_eq!(opener.num_chunks(t), want, "len={len} nsegs={nsegs} t={t}");
        }
        // t=0 clamps rather than dividing by zero.
        let sealer = StreamSealer::new(&k1, 64, 4);
        assert_eq!(sealer.num_chunks(0), 4);
    }

    #[test]
    fn segment_reorder_detected() {
        let k1 = Gcm::new(&[2u8; 16]);
        let m = msg(64 * 1024, 1);
        let (h, mut segs) = chop_encrypt(&k1, &m, 4);
        segs.swap(0, 1);
        assert!(chop_decrypt(&k1, &h, &segs).is_err());
    }

    #[test]
    fn segment_drop_detected() {
        let k1 = Gcm::new(&[2u8; 16]);
        let m = msg(64 * 1024, 2);
        let (h, mut segs) = chop_encrypt(&k1, &m, 4);
        segs.pop();
        assert!(chop_decrypt(&k1, &h, &segs).is_err());
        // Dropping an interior segment (shifting the rest up) also fails.
        let (h2, mut segs2) = chop_encrypt(&k1, &m, 4);
        segs2.remove(1);
        assert!(chop_decrypt(&k1, &h2, &segs2).is_err());
    }

    #[test]
    fn segment_duplicate_detected() {
        let k1 = Gcm::new(&[2u8; 16]);
        let m = msg(64 * 1024, 3);
        let (h, mut segs) = chop_encrypt(&k1, &m, 4);
        let dup = segs[1].clone();
        segs[2] = dup; // replay segment 2 in position 3
        assert!(chop_decrypt(&k1, &h, &segs).is_err());
    }

    #[test]
    fn header_tamper_detected() {
        let k1 = Gcm::new(&[3u8; 16]);
        let m = msg(128 * 1024, 4);
        let (h, segs) = chop_encrypt(&k1, &m, 8);
        // Tamper each header field; all must produce decryption failure.
        let mut bad_seed = h.clone();
        bad_seed.seed[0] ^= 1;
        assert!(chop_decrypt(&k1, &bad_seed, &segs).is_err());
        let mut bad_len = h.clone();
        bad_len.msg_len -= 1;
        assert!(chop_decrypt(&k1, &bad_len, &segs).is_err());
        let mut bad_seg = h.clone();
        bad_seg.seg_size /= 2;
        assert!(chop_decrypt(&k1, &bad_seg, &segs).is_err());
    }

    #[test]
    fn ciphertext_bitflip_detected_every_segment() {
        let k1 = Gcm::new(&[4u8; 16]);
        let m = msg(64 * 1024, 5);
        let (h, segs) = chop_encrypt(&k1, &m, 4);
        for i in 0..segs.len() {
            let mut bad = segs.clone();
            let mid = bad[i].len() / 2;
            bad[i][mid] ^= 0x80;
            assert!(chop_decrypt(&k1, &h, &bad).is_err(), "segment {i}");
        }
    }

    #[test]
    fn wrong_master_key_fails() {
        let k1 = Gcm::new(&[5u8; 16]);
        let other = Gcm::new(&[6u8; 16]);
        let m = msg(64 * 1024, 6);
        let (h, segs) = chop_encrypt(&k1, &m, 4);
        assert!(chop_decrypt(&other, &h, &segs).is_err());
    }

    #[test]
    fn subkey_differs_per_message() {
        let k1 = Gcm::new(&[7u8; 16]);
        let a = derive_subkey(&k1, &[1u8; 16]);
        let b = derive_subkey(&k1, &[2u8; 16]);
        assert_ne!(a, b);
    }

    #[test]
    fn nonce_layout_matches_paper() {
        let n = segment_nonce(0x01020304, true);
        assert_eq!(&n[..7], &[0u8; 7]); // [0]_7
        assert_eq!(n[7], 1); // [last]_1
        assert_eq!(&n[8..], &[1, 2, 3, 4]); // [i]_4
    }

    /// The paper's §IV key-separation attack: with a single key K used for
    /// both direct GCM and Algorithm 1, an adversary that knows a 16-byte
    /// direct-GCM plaintext can extract `L = AES_K(V)` (where `V = N‖[2]_4`
    /// is the first *data* counter block — GCM reserves counter 1 for the
    /// tag mask, so CTR data blocks start at 2) from `C = AES_K(V) ⊕ X`,
    /// then forge a valid chopped ciphertext using V as "seed" and L as
    /// subkey. With separate keys the forged message must fail.
    #[test]
    fn key_separation_attack() {
        let k = Gcm::new(&[0x11u8; 16]);

        // Victim encrypts a known 16-byte message X directly under K.
        let x = *b"known plaintext!";
        let nonce: [u8; 12] = [0x77u8; 12];
        let sealed = k.seal(&nonce, &[], &x);

        // Adversary extracts L = AES_K(V): the first CTR keystream block is
        // AES_K(N ‖ [2]_4) — GCM data counters start at 2 — so V = N‖[2]_4.
        let mut keystream = [0u8; 16];
        for i in 0..16 {
            keystream[i] = sealed[i] ^ x[i];
        }
        let mut v = [0u8; 16];
        v[..12].copy_from_slice(&nonce);
        v[12..16].copy_from_slice(&2u32.to_be_bytes());

        // Forge: encrypt an arbitrary large message under subkey L with
        // header seed V. Against the SAME key (single-key misuse), the
        // receiver accepts the forgery.
        let forged_msg = msg(64 * 1024, 99);
        let sub = Gcm::new(&keystream);
        let seg_size = (forged_msg.len() as u64).div_ceil(4);
        let header = Header {
            opcode: Opcode::Chopped,
            seed: v,
            msg_len: forged_msg.len() as u64,
            seg_size,
        };
        let nsegs = segment_count(header.msg_len, header.seg_size).unwrap();
        let mut segs = Vec::new();
        for i in 1..=nsegs {
            let start = (seg_size * (i as u64 - 1)) as usize;
            let end = ((start as u64 + seg_size) as usize).min(forged_msg.len());
            let mut buf = forged_msg[start..end].to_vec();
            let tag = sub.seal_in_place(&segment_nonce(i, i == nsegs), &[], &mut buf);
            buf.extend_from_slice(&tag);
            segs.push(buf);
        }

        // Misuse: victim decrypts chopped messages under the SAME key K.
        let accepted = chop_decrypt(&k, &header, &segs);
        assert_eq!(accepted.expect("single-key misuse accepts the forgery"), forged_msg);

        // Correct deployment: chopped messages use K1 ≠ K2; forgery fails.
        let k1_distinct = Gcm::new(&[0x22u8; 16]);
        assert!(chop_decrypt(&k1_distinct, &header, &segs).is_err());
    }

    #[test]
    fn wire_roundtrip_various_shapes() {
        let k1 = Gcm::new(&[21u8; 16]);
        let mut wire = Vec::new();
        for (len, nsegs) in
            [(1usize, 1u32), (100, 3), (65535, 8), (65536, 8), (65537, 8), (1 << 20, 64), (5, 16)]
        {
            let m = msg(len, len as u64 + 7);
            let h = chop_encrypt_into(&k1, &m, nsegs, &mut wire);
            let actual_segs = segment_count(h.msg_len, h.seg_size).unwrap() as usize;
            assert_eq!(wire.len(), len + actual_segs * TAG_LEN, "len={len} nsegs={nsegs}");
            let out = chop_decrypt_wire(&k1, &h, &wire).expect("roundtrip");
            assert_eq!(out, m, "len={len} nsegs={nsegs}");
        }
    }

    /// The contiguous wire image must be byte-identical to the legacy
    /// per-segment path under the same subkey: bodies in order, then tags
    /// in order. (Receivers of either layout interoperate.)
    #[test]
    fn wire_layout_matches_legacy_segments() {
        let k1 = Gcm::new(&[23u8; 16]);
        let m = msg(200_000, 9);
        let seed = [0x44u8; 16];
        let sealer = StreamSealer::with_seed(&k1, m.len(), 6, seed);
        let n = sealer.num_segments();
        let mut legacy_bodies = Vec::new();
        let mut legacy_tags = Vec::new();
        for i in 1..=n {
            let mut b = m[sealer.segment_range(i)].to_vec();
            let tag = sealer.seal_segment(i, &mut b);
            legacy_bodies.extend_from_slice(&b);
            legacy_tags.extend_from_slice(&tag);
        }
        let sealer2 = StreamSealer::with_seed(&k1, m.len(), 6, seed);
        let mut wire = vec![0u8; sealer2.chunk_wire_len(1, n)];
        wire[..m.len()].copy_from_slice(&m);
        sealer2.seal_chunk(1, n, &mut wire);
        assert_eq!(&wire[..m.len()], &legacy_bodies[..]);
        assert_eq!(&wire[m.len()..], &legacy_tags[..]);
    }

    #[test]
    fn wire_tamper_and_truncation_detected() {
        let k1 = Gcm::new(&[22u8; 16]);
        let m = msg(128 * 1024, 11);
        let mut wire = Vec::new();
        let h = chop_encrypt_into(&k1, &m, 8, &mut wire);
        for pos in [0usize, 1000, m.len() - 1, m.len(), wire.len() - 1] {
            let mut bad = wire.clone();
            bad[pos] ^= 1;
            assert!(chop_decrypt_wire(&k1, &h, &bad).is_err(), "pos={pos}");
        }
        assert!(chop_decrypt_wire(&k1, &h, &wire[..wire.len() - 1]).is_err());
        let mut longer = wire.clone();
        longer.push(0);
        assert!(chop_decrypt_wire(&k1, &h, &longer).is_err());
    }

    /// `Header::decode` must never panic, whatever bytes arrive.
    #[test]
    fn decode_random_inputs_never_panic() {
        let mut rng = SimRng::new(0xfeed);
        for _ in 0..2000 {
            let mut buf = [0u8; HEADER_LEN];
            rng.fill(&mut buf);
            let _ = Header::decode(&buf);
        }
        for len in 0..HEADER_LEN {
            assert!(Header::decode(&vec![0u8; len]).is_err(), "short input len={len}");
        }
    }

    /// Direct headers carry a 12-byte nonce with a zero pad and no segment
    /// size; Plain headers carry neither. Nonzero unused bytes are
    /// malformed and must be rejected at decode time.
    #[test]
    fn unused_header_bytes_must_be_zero() {
        let mut seed = [0u8; 16];
        seed[..NONCE_LEN].copy_from_slice(&[7u8; NONCE_LEN]);
        let direct = Header { opcode: Opcode::Direct, seed, msg_len: 10, seg_size: 0 };
        assert!(Header::decode(&direct.encode()).is_ok());
        let mut bad_pad = direct.clone();
        bad_pad.seed[NONCE_LEN] = 1;
        assert!(Header::decode(&bad_pad.encode()).is_err(), "nonzero nonce pad");
        let mut bad_seg = direct.clone();
        bad_seg.seg_size = 5;
        assert!(Header::decode(&bad_seg.encode()).is_err(), "direct with seg_size");

        let plain = Header { opcode: Opcode::Plain, seed: [0u8; 16], msg_len: 3, seg_size: 0 };
        assert!(Header::decode(&plain.encode()).is_ok());
        let mut bad_plain = plain.clone();
        bad_plain.seed[0] = 1;
        assert!(Header::decode(&bad_plain.encode()).is_err(), "plain with seed");
    }

    /// Cursors hand out logical bytes across extent boundaries in any
    /// request granularity.
    #[test]
    fn cursors_walk_extents_in_any_granularity() {
        let src: Vec<u8> = (0u8..=99).collect();
        let ext = [(2usize, 3usize), (10, 5), (40, 4)];
        let logical: Vec<u8> = ext
            .iter()
            .flat_map(|&(o, l)| src[o..o + l].iter().copied())
            .collect();
        for chunk in [1usize, 2, 5, 12] {
            let mut cur = GatherCursor::new(&src, &ext);
            assert_eq!(cur.remaining(), 12);
            let mut got = Vec::new();
            while cur.remaining() > 0 {
                let n = chunk.min(cur.remaining());
                let mut buf = vec![0u8; n];
                cur.copy_next(&mut buf);
                got.extend_from_slice(&buf);
            }
            assert_eq!(got, logical, "gather chunk={chunk}");

            // The push-style walk yields the identical byte stream.
            let mut cur = GatherCursor::new(&src, &ext);
            let mut pushed = Vec::new();
            while cur.remaining() > 0 {
                let n = chunk.min(cur.remaining());
                cur.append_to(&mut pushed, n);
            }
            assert_eq!(pushed, logical, "append chunk={chunk}");

            let mut dst = vec![0xEEu8; 100];
            let mut cur = ScatterCursor::new(&mut dst, &ext);
            let mut at = 0;
            while cur.remaining() > 0 {
                let n = chunk.min(cur.remaining());
                cur.copy_next(&logical[at..at + n]);
                at += n;
            }
            for &(o, l) in &ext {
                assert_eq!(&dst[o..o + l], &src[o..o + l], "scatter chunk={chunk}");
            }
            let touched: usize = ext.iter().map(|e| e.1).sum();
            assert_eq!(dst.iter().filter(|&&b| b != 0xEE).count(), touched);
        }
    }

    /// The fused gather-seal wire image must be byte-identical to the
    /// pack-then-seal reference under the same seed — for a genuinely
    /// strided layout AND for the degenerate contiguous one — on both
    /// crypto backends. Receivers cannot tell the paths apart.
    #[test]
    fn gather_seal_wire_image_matches_pack_then_seal() {
        for hw in [true, false] {
            let k1 = Gcm::with_backend(&[0x51u8; 16], hw);
            for (name, ext, span) in [
                ("strided", vec![(0usize, 4096usize), (8192, 4096), (20000, 120_000)], 140_192),
                ("degenerate", vec![(0usize, 128_192usize)], 128_192),
            ] {
                let src = msg(span, 77);
                let packed: Vec<u8> =
                    ext.iter().flat_map(|&(o, l)| src[o..o + l].iter().copied()).collect();
                let seed = [0x66u8; 16];
                let sealer = StreamSealer::with_seed(&k1, packed.len(), 6, seed);
                let n = sealer.num_segments();
                let mut wire_pack = vec![0u8; sealer.chunk_wire_len(1, n)];
                wire_pack[..packed.len()].copy_from_slice(&packed);
                sealer.seal_chunk(1, n, &mut wire_pack);

                let sealer2 = StreamSealer::with_seed(&k1, packed.len(), 6, seed);
                let mut wire_gather = vec![0u8; sealer2.chunk_wire_len(1, n)];
                let mut cur = GatherCursor::new(&src, &ext);
                sealer2.seal_chunk_gather(1, n, &mut cur, &mut wire_gather);
                assert_eq!(wire_gather, wire_pack, "hw={hw} {name}");
            }
        }
    }

    /// Gather-seal → open-scatter roundtrips a strided message; bytes
    /// outside the destination extents are never touched; any wire
    /// tamper is rejected. Both backends.
    #[test]
    fn gather_seal_open_scatter_roundtrip_and_tamper() {
        for hw in [true, false] {
            let k1 = Gcm::with_backend(&[0x52u8; 16], hw);
            let ext = [(16usize, 30_000usize), (40_000, 50_000), (100_000, 40_000)];
            let span = 140_016;
            let src = msg(span, 5 + hw as u64);
            let mut wire = Vec::new();
            let h = chop_encrypt_gather_into(&k1, &src, &ext, 8, &mut wire);
            assert_eq!(h.msg_len, 120_000);

            let mut dst = vec![0xEEu8; span];
            let mut scratch = wire.clone();
            chop_decrypt_wire_scatter(&k1, &h, &mut scratch, &mut dst, &ext)
                .expect("roundtrip hw={hw}");
            for &(o, l) in &ext {
                assert_eq!(&dst[o..o + l], &src[o..o + l], "hw={hw}");
            }
            let sel: usize = ext.iter().map(|e| e.1).sum();
            assert!(dst.iter().filter(|&&b| b != 0xEE).count() <= sel);
            assert!(dst[..16].iter().all(|&b| b == 0xEE), "gap before first extent");

            // Tamper anywhere in the wire -> clean failure.
            for pos in [0usize, 60_000, wire.len() - 1] {
                let mut bad = wire.clone();
                bad[pos] ^= 0x40;
                let mut dst2 = vec![0u8; span];
                assert!(
                    chop_decrypt_wire_scatter(&k1, &h, &mut bad, &mut dst2, &ext).is_err(),
                    "hw={hw} pos={pos}"
                );
            }
            // Truncated wire / wrong-capacity extents -> clean failure.
            let mut short = wire[..wire.len() - 1].to_vec();
            assert!(chop_decrypt_wire_scatter(&k1, &h, &mut short, &mut dst, &ext).is_err());
            let tiny = [(0usize, 100usize)];
            let mut scratch = wire.clone();
            assert!(
                chop_decrypt_wire_scatter(&k1, &h, &mut scratch, &mut dst, &tiny).is_err(),
                "hw={hw}: capacity smaller than msg_len must fail"
            );
        }
    }

    #[test]
    fn seed_uniqueness_statistical() {
        // Draw many Algorithm-1 seeds; all must be distinct (Proposition 1).
        let k1 = Gcm::new(&[9u8; 16]);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            let s = StreamSealer::new(&k1, 1024, 2);
            assert!(seen.insert(s.header().seed), "seed collision");
        }
    }

    #[test]
    fn band_ranges_cover_and_balance() {
        for n in [1u32, 2, 3, 7, 8, 16, 33] {
            for w in [1usize, 2, 3, 4, 7, 64] {
                let bands = band_ranges(n, w);
                assert!(!bands.is_empty());
                assert!(bands.len() <= w.min(n as usize));
                assert_eq!(bands[0].0, 1);
                assert_eq!(bands.last().unwrap().1, n);
                for win in bands.windows(2) {
                    assert_eq!(win[1].0, win[0].1 + 1, "contiguous bands");
                }
                let sizes: Vec<u32> = bands.iter().map(|&(a, b)| b - a + 1).collect();
                let (lo, hi) =
                    (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(hi - lo <= 1, "near-equal bands: n={n} w={w}");
            }
        }
    }

    #[test]
    fn cursor_skip_matches_copy_prefix() {
        // skip(n) must leave a cursor positioned exactly where consuming n
        // bytes would — across extent boundaries and zero-length extents.
        let src = msg(4096, 77);
        let ext = [(0usize, 500usize), (600, 0), (700, 1000), (2000, 900)];
        let total = 2400usize;
        let mut full = vec![0u8; total];
        GatherCursor::new(&src, &ext).copy_next(&mut full);
        for n in [0usize, 1, 499, 500, 501, 1499, 1500, 2399, 2400] {
            let mut a = GatherCursor::new(&src, &ext);
            a.skip(n);
            assert_eq!(a.remaining(), total - n);
            let mut tail = vec![0u8; total - n];
            a.copy_next(&mut tail);
            assert_eq!(tail, full[n..], "gather skip n={n}");

            // Scatter mirror: skip n, write the tail — the result must
            // match a full scatter with the first n logical bytes zeroed.
            let mut dst_skip = vec![0u8; 4096];
            let mut sc = ScatterCursor::new(&mut dst_skip, &ext);
            sc.skip(n);
            sc.copy_next(&full[n..]);
            let mut want = vec![0u8; 4096];
            let mut zeroed = full.clone();
            zeroed[..n].fill(0);
            ScatterCursor::new(&mut want, &ext).copy_next(&zeroed);
            assert_eq!(dst_skip, want, "scatter skip n={n}");
        }
    }

    /// The anchor property at unit scope: parallel banding over any worker
    /// count yields byte-identical wire to the serial seal, and the
    /// parallel open roundtrips it (both backends).
    #[test]
    fn parallel_seal_open_matches_serial_wire_image() {
        for hw in [true, false] {
            let k1 = Gcm::with_backend(&[0x61u8; 16], hw);
            let m = msg(200_001, 13);
            let seed = [0x5au8; 16];
            let mut serial = Vec::new();
            let h = chop_encrypt_into_seeded(&k1, &m, 6, seed, &mut serial);
            for w in [1usize, 2, 4, 7] {
                let pool = WorkerPool::new(w);
                let mut par = Vec::new();
                let hp = chop_encrypt_into_parallel_seeded(&k1, &m, 6, seed, &mut par, &pool);
                assert_eq!(hp, h, "hw={hw} w={w}");
                assert_eq!(par, serial, "hw={hw} w={w}");
                let back = chop_decrypt_wire_parallel(&k1, &h, &par, &pool).unwrap();
                assert_eq!(back, m, "hw={hw} w={w}");
                // Cross-compatibility: serial open of parallel wire.
                assert_eq!(chop_decrypt_wire(&k1, &h, &par).unwrap(), m);
            }
        }
    }

    /// Parallel open error latch: a corrupted segment anywhere surfaces as
    /// the same clean AuthError, the input wire stays untouched, and the
    /// pool keeps working (no deadlock, no poisoned workers).
    #[test]
    fn parallel_open_latches_clean_autherror() {
        let k1 = Gcm::new(&[0x62u8; 16]);
        let m = msg(160_000, 21);
        let pool = WorkerPool::new(4);
        let mut wire = Vec::new();
        let h = chop_encrypt_into(&k1, &m, 8, &mut wire);
        for pos in [0usize, 80_000, 159_999, 160_005] {
            let mut bad = wire.clone();
            bad[pos] ^= 1;
            let snapshot = bad.clone();
            assert!(
                chop_decrypt_wire_parallel(&k1, &h, &bad, &pool).is_err(),
                "pos={pos}"
            );
            assert_eq!(bad, snapshot, "input wire must stay untouched, pos={pos}");
        }
        // Pool is still fully usable for a good message afterwards.
        assert_eq!(chop_decrypt_wire_parallel(&k1, &h, &wire, &pool).unwrap(), m);
    }
}
