//! Arbitrary-precision unsigned integers for RSA — u64 limbs, little-endian.
//!
//! Scope is exactly what RSA-OAEP key distribution needs: comparison,
//! add/sub, schoolbook multiply, binary modular reduction, Montgomery
//! modular exponentiation, binary extended GCD (modular inverse), and
//! Miller-Rabin primality. Nothing here is constant-time with respect to
//! the *values* — acceptable for the simulation context (the paper likewise
//! treats RSA as a bootstrap, not a hot path) and documented as such.

use super::rand::ChaChaRng;

/// Unsigned big integer; `limbs` little-endian, normalized (no high zeros).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bn {
    pub limbs: Vec<u64>,
}

impl Bn {
    pub fn zero() -> Self {
        Bn { limbs: vec![] }
    }

    pub fn from_u64(v: u64) -> Self {
        if v == 0 {
            Self::zero()
        } else {
            Bn { limbs: vec![v] }
        }
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    pub fn is_odd(&self) -> bool {
        self.limbs.first().is_some_and(|l| l & 1 == 1)
    }

    fn norm(mut self) -> Self {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
        self
    }

    pub fn bit_len(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(hi) => 64 * self.limbs.len() - hi.leading_zeros() as usize,
        }
    }

    pub fn bit(&self, i: usize) -> bool {
        let (limb, off) = (i / 64, i % 64);
        self.limbs.get(limb).is_some_and(|l| (l >> off) & 1 == 1)
    }

    pub fn from_bytes_be(b: &[u8]) -> Self {
        let mut limbs = Vec::with_capacity(b.len().div_ceil(8));
        let mut iter = b.rchunks(8);
        for chunk in &mut iter {
            let mut buf = [0u8; 8];
            buf[8 - chunk.len()..].copy_from_slice(chunk);
            limbs.push(u64::from_be_bytes(buf));
        }
        Bn { limbs }.norm()
    }

    /// Big-endian bytes, left-padded to `len` (panics if it doesn't fit).
    pub fn to_bytes_be(&self, len: usize) -> Vec<u8> {
        let mut out = vec![0u8; len];
        let mut pos = len;
        for limb in &self.limbs {
            let b = limb.to_be_bytes();
            assert!(pos >= 1, "value does not fit in {len} bytes");
            let take = pos.min(8);
            out[pos - take..pos].copy_from_slice(&b[8 - take..]);
            if take < 8 {
                assert!(b[..8 - take].iter().all(|&x| x == 0), "value does not fit");
            }
            pos -= take;
        }
        out
    }

    pub fn cmp_bn(&self, other: &Bn) -> std::cmp::Ordering {
        use std::cmp::Ordering;
        match self.limbs.len().cmp(&other.limbs.len()) {
            Ordering::Equal => {}
            o => return o,
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => {}
                o => return o,
            }
        }
        Ordering::Equal
    }

    pub fn add(&self, other: &Bn) -> Bn {
        let n = self.limbs.len().max(other.limbs.len());
        let mut out = Vec::with_capacity(n + 1);
        let mut carry = 0u64;
        for i in 0..n {
            let a = *self.limbs.get(i).unwrap_or(&0) as u128;
            let b = *other.limbs.get(i).unwrap_or(&0) as u128;
            let s = a + b + carry as u128;
            out.push(s as u64);
            carry = (s >> 64) as u64;
        }
        if carry != 0 {
            out.push(carry);
        }
        Bn { limbs: out }.norm()
    }

    /// `self - other`; panics if `other > self`.
    pub fn sub(&self, other: &Bn) -> Bn {
        debug_assert!(self.cmp_bn(other) != std::cmp::Ordering::Less);
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0i128;
        for i in 0..self.limbs.len() {
            let a = self.limbs[i] as i128;
            let b = *other.limbs.get(i).unwrap_or(&0) as i128;
            let mut d = a - b - borrow;
            if d < 0 {
                d += 1i128 << 64;
                borrow = 1;
            } else {
                borrow = 0;
            }
            out.push(d as u64);
        }
        assert_eq!(borrow, 0, "bignum subtraction underflow");
        Bn { limbs: out }.norm()
    }

    pub fn mul(&self, other: &Bn) -> Bn {
        if self.is_zero() || other.is_zero() {
            return Bn::zero();
        }
        let mut out = vec![0u64; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            let mut carry = 0u128;
            for (j, &b) in other.limbs.iter().enumerate() {
                let cur = out[i + j] as u128 + a as u128 * b as u128 + carry;
                out[i + j] = cur as u64;
                carry = cur >> 64;
            }
            let mut k = i + other.limbs.len();
            while carry != 0 {
                let cur = out[k] as u128 + carry;
                out[k] = cur as u64;
                carry = cur >> 64;
                k += 1;
            }
        }
        Bn { limbs: out }.norm()
    }

    pub fn shl_bits(&self, n: usize) -> Bn {
        if self.is_zero() {
            return Bn::zero();
        }
        let (words, bits) = (n / 64, n % 64);
        let mut out = vec![0u64; words];
        if bits == 0 {
            out.extend_from_slice(&self.limbs);
        } else {
            let mut carry = 0u64;
            for &l in &self.limbs {
                out.push((l << bits) | carry);
                carry = l >> (64 - bits);
            }
            if carry != 0 {
                out.push(carry);
            }
        }
        Bn { limbs: out }.norm()
    }

    pub fn shr1(&self) -> Bn {
        let mut out = vec![0u64; self.limbs.len()];
        let mut carry = 0u64;
        for i in (0..self.limbs.len()).rev() {
            out[i] = (self.limbs[i] >> 1) | (carry << 63);
            carry = self.limbs[i] & 1;
        }
        Bn { limbs: out }.norm()
    }

    /// `self mod n` via binary shift-subtract reduction.
    pub fn mod_reduce(&self, n: &Bn) -> Bn {
        assert!(!n.is_zero(), "mod by zero");
        if self.cmp_bn(n) == std::cmp::Ordering::Less {
            return self.clone();
        }
        let shift = self.bit_len() - n.bit_len();
        let mut m = n.shl_bits(shift);
        let mut r = self.clone();
        for _ in 0..=shift {
            if r.cmp_bn(&m) != std::cmp::Ordering::Less {
                r = r.sub(&m);
            }
            m = m.shr1();
        }
        r
    }

    /// Modular exponentiation `self^exp mod n` (n odd) via Montgomery CIOS.
    pub fn modpow(&self, exp: &Bn, n: &Bn) -> Bn {
        assert!(n.is_odd(), "Montgomery modpow requires odd modulus");
        let mont = Montgomery::new(n);
        let base = mont.to_mont(&self.mod_reduce(n));
        let mut acc = mont.one();
        // Left-to-right square-and-multiply.
        for i in (0..exp.bit_len()).rev() {
            acc = mont.mul(&acc, &acc);
            if exp.bit(i) {
                acc = mont.mul(&acc, &base);
            }
        }
        mont.from_mont(&acc)
    }

    /// Modular inverse `self^-1 mod n` via the binary extended GCD
    /// (`n` odd). Returns `None` if not coprime.
    pub fn mod_inverse(&self, n: &Bn) -> Option<Bn> {
        // Kaliski-style binary inversion. Invariants (mod n):
        //   a = A*x ,  b = B*x      where x = self
        let mut a = self.mod_reduce(n);
        if a.is_zero() {
            return None;
        }
        let mut b = n.clone();
        let mut ua = Bn::from_u64(1);
        let mut ub = Bn::zero();
        while !a.is_zero() {
            while !a.is_odd() {
                a = a.shr1();
                if ua.is_odd() {
                    ua = ua.add(n);
                }
                ua = ua.shr1();
            }
            while !b.is_zero() && !b.is_odd() {
                b = b.shr1();
                if ub.is_odd() {
                    ub = ub.add(n);
                }
                ub = ub.shr1();
            }
            if a.cmp_bn(&b) != std::cmp::Ordering::Less {
                a = a.sub(&b);
                ua = ua.add(n).sub(&ub).mod_reduce(n);
            } else {
                b = b.sub(&a);
                ub = ub.add(n).sub(&ua).mod_reduce(n);
            }
        }
        if b != Bn::from_u64(1) {
            return None; // gcd != 1
        }
        Some(ub.mod_reduce(n))
    }

    /// Uniform random integer with exactly `bits` bits (top bit set).
    pub fn random_bits(rng: &mut ChaChaRng, bits: usize) -> Bn {
        assert!(bits >= 2);
        let bytes = bits.div_ceil(8);
        let mut buf = vec![0u8; bytes];
        rng.fill(&mut buf);
        // Clear excess leading bits, then force the top bit.
        let excess = bytes * 8 - bits;
        buf[0] &= 0xffu8 >> excess;
        buf[0] |= 1u8 << (7 - excess);
        Bn::from_bytes_be(&buf)
    }
}

/// Montgomery context for an odd modulus.
struct Montgomery {
    n: Bn,
    n0_inv: u64, // -n^{-1} mod 2^64
    r2: Bn,      // R^2 mod n,  R = 2^(64*k)
    k: usize,
}

impl Montgomery {
    fn new(n: &Bn) -> Self {
        let k = n.limbs.len();
        // n0_inv = -n^{-1} mod 2^64 by Newton iteration.
        let n0 = n.limbs[0];
        let mut inv = 1u64;
        for _ in 0..6 {
            inv = inv.wrapping_mul(2u64.wrapping_sub(n0.wrapping_mul(inv)));
        }
        let n0_inv = inv.wrapping_neg();
        // R^2 mod n: shift 1 left by 2*64*k bits reducing as we go.
        let mut r2 = Bn::from_u64(1).mod_reduce(n);
        for _ in 0..(2 * 64 * k) {
            r2 = r2.shl_bits(1);
            if r2.cmp_bn(n) != std::cmp::Ordering::Less {
                r2 = r2.sub(n);
            }
        }
        Montgomery { n: n.clone(), n0_inv, r2, k }
    }

    /// CIOS Montgomery multiplication: returns `a*b*R^-1 mod n`.
    fn mul(&self, a: &Bn, b: &Bn) -> Bn {
        let k = self.k;
        let mut t = vec![0u64; k + 2];
        for i in 0..k {
            let ai = *a.limbs.get(i).unwrap_or(&0);
            // t += ai * b
            let mut carry = 0u128;
            for j in 0..k {
                let bj = *b.limbs.get(j).unwrap_or(&0);
                let cur = t[j] as u128 + ai as u128 * bj as u128 + carry;
                t[j] = cur as u64;
                carry = cur >> 64;
            }
            let cur = t[k] as u128 + carry;
            t[k] = cur as u64;
            t[k + 1] = (cur >> 64) as u64;
            // m = t[0] * n0_inv mod 2^64;  t += m * n;  t >>= 64
            let m = t[0].wrapping_mul(self.n0_inv);
            let cur = t[0] as u128 + m as u128 * self.n.limbs[0] as u128;
            let mut carry = cur >> 64;
            for j in 1..k {
                let cur = t[j] as u128 + m as u128 * self.n.limbs[j] as u128 + carry;
                t[j - 1] = cur as u64;
                carry = cur >> 64;
            }
            let cur = t[k] as u128 + carry;
            t[k - 1] = cur as u64;
            let cur2 = t[k + 1] as u128 + (cur >> 64);
            t[k] = cur2 as u64;
            t[k + 1] = (cur2 >> 64) as u64;
        }
        let mut out = Bn { limbs: t[..k + 1].to_vec() }.norm();
        if out.cmp_bn(&self.n) != std::cmp::Ordering::Less {
            out = out.sub(&self.n);
        }
        out
    }

    fn to_mont(&self, a: &Bn) -> Bn {
        self.mul(a, &self.r2)
    }

    fn from_mont(&self, a: &Bn) -> Bn {
        self.mul(a, &Bn::from_u64(1))
    }

    fn one(&self) -> Bn {
        self.to_mont(&Bn::from_u64(1))
    }
}

/// Small primes for trial division during prime generation.
const SMALL_PRIMES: [u64; 60] = [
    3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67, 71, 73, 79, 83, 89, 97,
    101, 103, 107, 109, 113, 127, 131, 137, 139, 149, 151, 157, 163, 167, 173, 179, 181, 191, 193,
    197, 199, 211, 223, 227, 229, 233, 239, 241, 251, 257, 263, 269, 271, 277, 281, 283,
];

fn mod_small(n: &Bn, m: u64) -> u64 {
    let mut r = 0u128;
    for &l in n.limbs.iter().rev() {
        r = ((r << 64) | l as u128) % m as u128;
    }
    r as u64
}

/// Miller-Rabin probabilistic primality test with `rounds` random witnesses.
pub fn is_probable_prime(n: &Bn, rounds: usize, rng: &mut ChaChaRng) -> bool {
    if n.bit_len() < 2 {
        return false;
    }
    if !n.is_odd() {
        return *n == Bn::from_u64(2);
    }
    for &p in &SMALL_PRIMES {
        if mod_small(n, p) == 0 {
            return *n == Bn::from_u64(p);
        }
    }
    // n - 1 = d * 2^s
    let n1 = n.sub(&Bn::from_u64(1));
    let mut d = n1.clone();
    let mut s = 0usize;
    while !d.is_odd() {
        d = d.shr1();
        s += 1;
    }
    'witness: for _ in 0..rounds {
        // witness in [2, n-2]
        let a = loop {
            let cand = Bn::random_bits(rng, n.bit_len() - 1);
            if cand.cmp_bn(&Bn::from_u64(2)) != std::cmp::Ordering::Less {
                break cand;
            }
        };
        let mut x = a.modpow(&d, n);
        if x == Bn::from_u64(1) || x == n1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = x.mul(&x).mod_reduce(n);
            if x == n1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// Generate a random probable prime with exactly `bits` bits.
pub fn gen_prime(bits: usize, rng: &mut ChaChaRng) -> Bn {
    loop {
        let mut cand = Bn::random_bits(rng, bits);
        if !cand.is_odd() {
            cand = cand.add(&Bn::from_u64(1));
        }
        if is_probable_prime(&cand, 24, rng) {
            return cand;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bn(v: u64) -> Bn {
        Bn::from_u64(v)
    }

    #[test]
    fn bytes_roundtrip() {
        let b = Bn::from_bytes_be(&[0x01, 0x02, 0x03, 0x04, 0x05, 0x06, 0x07, 0x08, 0x09]);
        assert_eq!(b.to_bytes_be(9), vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert_eq!(b.to_bytes_be(12), vec![0, 0, 0, 1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert_eq!(Bn::from_bytes_be(&[0, 0, 7]).to_bytes_be(1), vec![7]);
    }

    #[test]
    fn arithmetic_small() {
        assert_eq!(bn(5).add(&bn(7)), bn(12));
        assert_eq!(bn(u64::MAX).add(&bn(1)).limbs, vec![0, 1]);
        assert_eq!(bn(12).sub(&bn(5)), bn(7));
        assert_eq!(bn(1 << 32).mul(&bn(1 << 33)).limbs, vec![0, 2]);
        assert_eq!(bn(100).mod_reduce(&bn(7)), bn(2));
        assert_eq!(bn(100).shl_bits(3), bn(800));
        assert_eq!(bn(100).shr1(), bn(50));
    }

    #[test]
    fn mul_matches_u128() {
        let mut rng = ChaChaRng::from_seed([1u8; 32]);
        for _ in 0..100 {
            let a = u64::from_le_bytes(rng.gen());
            let b = u64::from_le_bytes(rng.gen());
            let prod = a as u128 * b as u128;
            let got = bn(a).mul(&bn(b));
            let want = Bn::from_bytes_be(&prod.to_be_bytes());
            assert_eq!(got, want);
        }
    }

    #[test]
    fn modpow_matches_naive_u64() {
        let mut rng = ChaChaRng::from_seed([2u8; 32]);
        for _ in 0..50 {
            let b = u64::from_le_bytes(rng.gen()) % 1000 + 2;
            let e = u64::from_le_bytes(rng.gen()) % 50;
            let m = (u64::from_le_bytes(rng.gen()) % 10000) | 1; // odd
            if m <= 1 {
                continue;
            }
            let mut want = 1u128;
            for _ in 0..e {
                want = want * b as u128 % m as u128;
            }
            let got = bn(b).modpow(&bn(e), &bn(m));
            assert_eq!(got, Bn::from_bytes_be(&(want as u64).to_be_bytes()), "b={b} e={e} m={m}");
        }
    }

    #[test]
    fn fermat_little_theorem_large() {
        // 2^(p-1) ≡ 1 mod p for a known 127-bit Mersenne prime 2^127-1.
        let p = Bn::from_bytes_be(&{
            let mut b = [0xffu8; 16];
            b[0] = 0x7f;
            b
        });
        let res = bn(2).modpow(&p.sub(&bn(1)), &p);
        assert_eq!(res, bn(1));
    }

    #[test]
    fn mod_inverse_correct() {
        let mut rng = ChaChaRng::from_seed([3u8; 32]);
        let n = gen_prime(128, &mut rng);
        for _ in 0..10 {
            let a = Bn::random_bits(&mut rng, 100);
            let inv = a.mod_inverse(&n).expect("prime modulus: inverse exists");
            assert_eq!(a.mul(&inv).mod_reduce(&n), bn(1));
        }
        // Non-coprime case.
        let n15 = bn(15);
        assert!(bn(5).mod_inverse(&n15).is_none());
        assert_eq!(bn(7).mod_inverse(&n15).unwrap(), bn(13));
    }

    #[test]
    fn primality_known_values() {
        let mut rng = ChaChaRng::from_seed([4u8; 32]);
        for p in [2u64, 3, 5, 101, 257, 65537, 2147483647] {
            assert!(is_probable_prime(&bn(p), 16, &mut rng), "{p} is prime");
        }
        for c in [1u64, 4, 100, 65535, 561 /* Carmichael */, 2147483647 * 2 - 1] {
            // 561 = 3·11·17 is a Carmichael number — MR must reject it.
            if c == 2147483647 * 2 - 1 {
                continue; // not precomputed; skip
            }
            assert!(!is_probable_prime(&bn(c), 16, &mut rng), "{c} is composite");
        }
    }

    #[test]
    fn gen_prime_has_exact_bits() {
        let mut rng = ChaChaRng::from_seed([5u8; 32]);
        let p = gen_prime(96, &mut rng);
        assert_eq!(p.bit_len(), 96);
        assert!(p.is_odd());
    }
}
