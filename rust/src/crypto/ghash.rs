//! GHASH — the GF(2^128) universal hash of GCM (NIST SP 800-38D §6.3/§6.4).
//!
//! Two portable implementations live here: field elements are `u128`
//! values loaded big-endian from 16-byte blocks.
//!
//! * [`GhashSoft`] — the bit-serial right-shift algorithm of SP 800-38D
//!   Algorithm 1 (128 iterations per block). It is the *correctness
//!   reference* for everything else: the PCLMULQDQ path in
//!   [`super::clmul`] and the table-driven path below.
//! * [`GhashTableKey`] / [`GhashTable`] — Shoup-style 4-bit precomputed
//!   tables: 16 multiples of `H` plus a key-independent reduction table,
//!   32 table lookups per block instead of 128 shift/xor rounds. This is
//!   the portable *hot* path used by the fused GCM kernel; its setup is a
//!   handful of shifts and xors, cheap enough for per-message subkeys.

/// The GCM reduction polynomial constant `R = 11100001 ‖ 0^120`.
const R: u128 = 0xE1u128 << 120;

/// Multiply a field element by `x` (one right shift with conditional
/// reduction — SP 800-38D's `V` update step).
#[inline]
const fn mul_x(v: u128) -> u128 {
    let shifted = v >> 1;
    if v & 1 == 1 {
        shifted ^ R
    } else {
        shifted
    }
}

/// Key-independent reduction table for the 4-bit Shoup walk:
/// `RED4[b] = e(b) · x^4` where `e(b)` is the element whose four lowest
/// representation bits are `b` (coefficients `x^124..x^127`). Shifting the
/// accumulator right by a nibble pushes those coefficients past `x^127`;
/// this table folds them back per the GCM polynomial.
static RED4: [u128; 16] = {
    let mut t = [0u128; 16];
    let mut b = 0usize;
    while b < 16 {
        let mut z = b as u128;
        let mut i = 0;
        while i < 4 {
            z = mul_x(z);
            i += 1;
        }
        t[b] = z;
        b += 1;
    }
    t
};

/// Multiply two field elements per SP 800-38D Algorithm 1 (`X • Y`).
pub fn gf128_mul(x: u128, y: u128) -> u128 {
    let mut z = 0u128;
    let mut v = y;
    for i in 0..128 {
        if (x >> (127 - i)) & 1 == 1 {
            z ^= v;
        }
        let lsb = v & 1;
        v >>= 1;
        if lsb == 1 {
            v ^= R;
        }
    }
    z
}

/// Load a 16-byte block as a field element.
#[inline]
pub fn block_to_elem(b: &[u8]) -> u128 {
    let mut buf = [0u8; 16];
    buf[..b.len()].copy_from_slice(b); // implicit zero-pad for short tails
    u128::from_be_bytes(buf)
}

/// Incremental GHASH accumulator over the hash subkey `H`.
///
/// `update` consumes full or partial blocks (a partial block is zero-padded,
/// exactly as the GHASH definition pads the tails of A and C).
#[derive(Clone)]
pub struct GhashSoft {
    h: u128,
    y: u128,
}

impl GhashSoft {
    pub fn new(h: u128) -> Self {
        GhashSoft { h, y: 0 }
    }

    /// Absorb `data`, treating it as a sequence of 16-byte blocks with the
    /// final partial block zero-padded. GHASH over a byte string that is
    /// not block-aligned only occurs at the A/C boundaries of GCM, which is
    /// how callers use it.
    pub fn update(&mut self, data: &[u8]) {
        for chunk in data.chunks(16) {
            self.y = gf128_mul(self.y ^ block_to_elem(chunk), self.h);
        }
    }

    /// Absorb the GCM length block `[len(A)]_64 ‖ [len(C)]_64` (bit lengths).
    pub fn update_lengths(&mut self, aad_bytes: u64, ct_bytes: u64) {
        let block = ((aad_bytes as u128 * 8) << 64) | (ct_bytes as u128 * 8);
        self.y = gf128_mul(self.y ^ block, self.h);
    }

    /// Finalize, returning the GHASH output block.
    pub fn finalize(&self) -> [u8; 16] {
        self.y.to_be_bytes()
    }

    pub fn raw(&self) -> u128 {
        self.y
    }
}

impl Drop for GhashSoft {
    /// Volatile-wipe `H` (key material) and the running accumulator
    /// (keystream-derived) — see [`super::wipe`].
    fn drop(&mut self) {
        crate::crypto::wipe::wipe_value(&mut self.h);
        crate::crypto::wipe::wipe_value(&mut self.y);
    }
}

/// Precomputed 4-bit Shoup table for one hash subkey `H`: `m[b] = e(b)·H`
/// where `e(b)` places the four bits of `b` at coefficients `x^0..x^3`
/// (so `e(8)` is the multiplicative identity and `m[8] = H`).
///
/// Setup is 3 `mul_x` shifts plus a dozen xors — per-message subkey
/// construction stays cheap (the whole table is 256 bytes).
#[derive(Clone)]
pub struct GhashTableKey {
    m: [u128; 16],
}

impl GhashTableKey {
    pub fn new(h: u128) -> Self {
        let mut m = [0u128; 16];
        // Single-bit entries by repeated multiply-by-x from H = e(8)·H …
        m[8] = h;
        m[4] = mul_x(m[8]);
        m[2] = mul_x(m[4]);
        m[1] = mul_x(m[2]);
        // … composite entries by linearity.
        for b in [3usize, 5, 6, 7, 9, 10, 11, 12, 13, 14, 15] {
            let low = b & b.wrapping_neg(); // lowest set bit
            m[b] = m[low] ^ m[b ^ low];
        }
        GhashTableKey { m }
    }

    /// `x · H` via the 4-bit table walk: Horner over the 32 nibbles of `x`
    /// from the lowest representation nibble (highest power of `x^4`) up,
    /// each step one reduction lookup and one multiple lookup.
    #[inline]
    pub fn mul(&self, x: u128) -> u128 {
        let mut z = 0u128;
        let mut shift = 0u32;
        while shift < 128 {
            z = (z >> 4) ^ RED4[(z & 0xF) as usize];
            z ^= self.m[((x >> shift) & 0xF) as usize];
            shift += 4;
        }
        z
    }
}

impl Drop for GhashTableKey {
    /// Volatile-wipe the multiple table: every entry is a known multiple of
    /// the hash subkey `H`, so the table *is* key material (see
    /// [`super::wipe`]).
    fn drop(&mut self) {
        crate::crypto::wipe::wipe_value(&mut self.m);
    }
}

/// Incremental GHASH accumulator over a precomputed [`GhashTableKey`] —
/// same API shape as [`GhashSoft`], used by the fused portable GCM kernel.
pub struct GhashTable<'k> {
    key: &'k GhashTableKey,
    y: u128,
}

impl<'k> GhashTable<'k> {
    pub fn new(key: &'k GhashTableKey) -> Self {
        GhashTable { key, y: 0 }
    }

    /// Absorb one full 16-byte block (no padding needed — hot path).
    #[inline]
    pub fn absorb_block(&mut self, block: &[u8; 16]) {
        self.y = self.key.mul(self.y ^ u128::from_be_bytes(*block));
    }

    /// Absorb `data` with the final partial block zero-padded (same
    /// contract as [`GhashSoft::update`]).
    pub fn update(&mut self, data: &[u8]) {
        for chunk in data.chunks(16) {
            self.y = self.key.mul(self.y ^ block_to_elem(chunk));
        }
    }

    /// Absorb the GCM length block `[len(A)]_64 ‖ [len(C)]_64` (bit lengths).
    pub fn update_lengths(&mut self, aad_bytes: u64, ct_bytes: u64) {
        let block = ((aad_bytes as u128 * 8) << 64) | (ct_bytes as u128 * 8);
        self.y = self.key.mul(self.y ^ block);
    }

    pub fn finalize(&self) -> [u8; 16] {
        self.y.to_be_bytes()
    }

    /// Absorb the length block and finalize in one step (the tail of every
    /// fused-kernel sweep).
    pub fn finalize_tag(&mut self, aad_bytes: u64, ct_bytes: u64) -> [u8; 16] {
        self.update_lengths(aad_bytes, ct_bytes);
        self.finalize()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_identity_and_zero() {
        // The multiplicative identity of this representation is the element
        // with only the x^0 coefficient set, i.e. the MSB-first bit 0 = 0x80..0.
        let one = 1u128 << 127;
        for x in [0u128, 1, one, 0xdeadbeef_u128 << 64, u128::MAX] {
            assert_eq!(gf128_mul(x, one), x, "x * 1 == x");
            assert_eq!(gf128_mul(one, x), x, "1 * x == x");
            assert_eq!(gf128_mul(x, 0), 0);
        }
    }

    #[test]
    fn field_commutative_distributive() {
        let mut st = 0x9e3779b97f4a7c15u128;
        let mut next = move || {
            st = st.wrapping_mul(0x2545F4914F6CDD1D).wrapping_add(0x9E3779B9);
            st ^ (st << 64)
        };
        for _ in 0..50 {
            let (a, b, c) = (next(), next(), next());
            assert_eq!(gf128_mul(a, b), gf128_mul(b, a));
            assert_eq!(gf128_mul(a, b ^ c), gf128_mul(a, b) ^ gf128_mul(a, c));
            // associativity
            assert_eq!(gf128_mul(gf128_mul(a, b), c), gf128_mul(a, gf128_mul(b, c)));
        }
    }

    /// GHASH known-answer: from NIST GCM test case 2 intermediates.
    /// H = AES_0(0^128) = 66e94bd4ef8a2c3b884cfa59ca342b2e,
    /// GHASH(H, {}, C=0388dace60b6a392f328c2b971b2fe78)
    ///   = f38cbb1ad69223dcc3457ae5b6b0f885.
    #[test]
    fn ghash_known_answer() {
        let h = u128::from_be_bytes([
            0x66, 0xe9, 0x4b, 0xd4, 0xef, 0x8a, 0x2c, 0x3b, 0x88, 0x4c, 0xfa, 0x59, 0xca, 0x34,
            0x2b, 0x2e,
        ]);
        let c: [u8; 16] = [
            0x03, 0x88, 0xda, 0xce, 0x60, 0xb6, 0xa3, 0x92, 0xf3, 0x28, 0xc2, 0xb9, 0x71, 0xb2,
            0xfe, 0x78,
        ];
        let mut g = GhashSoft::new(h);
        g.update(&c);
        g.update_lengths(0, 16);
        let expect: [u8; 16] = [
            0xf3, 0x8c, 0xbb, 0x1a, 0xd6, 0x92, 0x23, 0xdc, 0xc3, 0x45, 0x7a, 0xe5, 0xb6, 0xb0,
            0xf8, 0x85,
        ];
        assert_eq!(g.finalize(), expect);
    }

    /// The 4-bit table multiply must agree with the bit-serial reference
    /// for random elements (including the identity and all-ones edges).
    #[test]
    fn table_mul_matches_bit_serial() {
        let mut st = 0xA076_1D64_78BD_642Fu128;
        let mut next = move || {
            st = st.wrapping_mul(0x2545F4914F6CDD1D).wrapping_add(0x9E3779B9);
            st ^ (st << 64) ^ (st >> 17)
        };
        for _ in 0..200 {
            let (h, x) = (next(), next());
            let key = GhashTableKey::new(h);
            assert_eq!(key.mul(x), gf128_mul(x, h), "h={h:032x} x={x:032x}");
        }
        let one = 1u128 << 127;
        let key = GhashTableKey::new(one);
        for x in [0u128, 1, one, u128::MAX] {
            assert_eq!(key.mul(x), x, "x·1 == x");
        }
    }

    /// The table-driven accumulator produces the same digest as GhashSoft
    /// over awkward byte lengths (partial tails, empty input).
    #[test]
    fn table_accumulator_matches_soft() {
        let h = 0x66e94bd4_ef8a2c3b_884cfa59_ca342b2eu128;
        let key = GhashTableKey::new(h);
        for len in [0usize, 1, 15, 16, 17, 63, 64, 65, 127, 128, 129, 1000] {
            let data: Vec<u8> = (0..len).map(|i| (i * 31 + 7) as u8).collect();
            let mut soft = GhashSoft::new(h);
            soft.update(b"aad bytes");
            soft.update(&data);
            soft.update_lengths(9, len as u64);
            let mut tab = GhashTable::new(&key);
            tab.update(b"aad bytes");
            tab.update(&data);
            tab.update_lengths(9, len as u64);
            assert_eq!(tab.finalize(), soft.finalize(), "len={len}");
        }
    }

    /// `absorb_block` is the block-aligned fast path of `update`.
    #[test]
    fn absorb_block_matches_update() {
        let key = GhashTableKey::new(0x1234_5678_9abc_def0_0fed_cba9_8765_4321u128);
        let data = [0x5au8; 64];
        let mut a = GhashTable::new(&key);
        a.update(&data);
        let mut b = GhashTable::new(&key);
        for chunk in data.chunks_exact(16) {
            b.absorb_block(chunk.try_into().unwrap());
        }
        assert_eq!(a.finalize(), b.finalize());
    }

    #[test]
    fn partial_block_padding_matches_manual_pad() {
        let h = 0x12345678_9abcdef0_0fedcba9_87654321u128;
        let data = [0xaau8; 21]; // 1 full block + 5-byte tail
        let mut a = GhashSoft::new(h);
        a.update(&data);
        let mut padded = [0u8; 32];
        padded[..21].copy_from_slice(&data);
        let mut b = GhashSoft::new(h);
        b.update(&padded);
        assert_eq!(a.finalize(), b.finalize());
    }
}
