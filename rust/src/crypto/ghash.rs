//! GHASH — the GF(2^128) universal hash of GCM (NIST SP 800-38D §6.3/§6.4).
//!
//! This module holds the portable software path: field elements are `u128`
//! values loaded big-endian from 16-byte blocks, multiplied with the
//! bit-serial right-shift algorithm of SP 800-38D Algorithm 1. It is the
//! correctness reference for the PCLMULQDQ path in [`super::clmul`].

/// The GCM reduction polynomial constant `R = 11100001 ‖ 0^120`.
const R: u128 = 0xE1u128 << 120;

/// Multiply two field elements per SP 800-38D Algorithm 1 (`X • Y`).
pub fn gf128_mul(x: u128, y: u128) -> u128 {
    let mut z = 0u128;
    let mut v = y;
    for i in 0..128 {
        if (x >> (127 - i)) & 1 == 1 {
            z ^= v;
        }
        let lsb = v & 1;
        v >>= 1;
        if lsb == 1 {
            v ^= R;
        }
    }
    z
}

/// Load a 16-byte block as a field element.
#[inline]
pub fn block_to_elem(b: &[u8]) -> u128 {
    let mut buf = [0u8; 16];
    buf[..b.len()].copy_from_slice(b); // implicit zero-pad for short tails
    u128::from_be_bytes(buf)
}

/// Incremental GHASH accumulator over the hash subkey `H`.
///
/// `update` consumes full or partial blocks (a partial block is zero-padded,
/// exactly as the GHASH definition pads the tails of A and C).
#[derive(Clone)]
pub struct GhashSoft {
    h: u128,
    y: u128,
}

impl GhashSoft {
    pub fn new(h: u128) -> Self {
        GhashSoft { h, y: 0 }
    }

    /// Absorb `data`, treating it as a sequence of 16-byte blocks with the
    /// final partial block zero-padded. GHASH over a byte string that is
    /// not block-aligned only occurs at the A/C boundaries of GCM, which is
    /// how callers use it.
    pub fn update(&mut self, data: &[u8]) {
        for chunk in data.chunks(16) {
            self.y = gf128_mul(self.y ^ block_to_elem(chunk), self.h);
        }
    }

    /// Absorb the GCM length block `[len(A)]_64 ‖ [len(C)]_64` (bit lengths).
    pub fn update_lengths(&mut self, aad_bytes: u64, ct_bytes: u64) {
        let block = ((aad_bytes as u128 * 8) << 64) | (ct_bytes as u128 * 8);
        self.y = gf128_mul(self.y ^ block, self.h);
    }

    /// Finalize, returning the GHASH output block.
    pub fn finalize(&self) -> [u8; 16] {
        self.y.to_be_bytes()
    }

    pub fn raw(&self) -> u128 {
        self.y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_identity_and_zero() {
        // The multiplicative identity of this representation is the element
        // with only the x^0 coefficient set, i.e. the MSB-first bit 0 = 0x80..0.
        let one = 1u128 << 127;
        for x in [0u128, 1, one, 0xdeadbeef_u128 << 64, u128::MAX] {
            assert_eq!(gf128_mul(x, one), x, "x * 1 == x");
            assert_eq!(gf128_mul(one, x), x, "1 * x == x");
            assert_eq!(gf128_mul(x, 0), 0);
        }
    }

    #[test]
    fn field_commutative_distributive() {
        let mut st = 0x9e3779b97f4a7c15u128;
        let mut next = move || {
            st = st.wrapping_mul(0x2545F4914F6CDD1D).wrapping_add(0x9E3779B9);
            st ^ (st << 64)
        };
        for _ in 0..50 {
            let (a, b, c) = (next(), next(), next());
            assert_eq!(gf128_mul(a, b), gf128_mul(b, a));
            assert_eq!(gf128_mul(a, b ^ c), gf128_mul(a, b) ^ gf128_mul(a, c));
            // associativity
            assert_eq!(gf128_mul(gf128_mul(a, b), c), gf128_mul(a, gf128_mul(b, c)));
        }
    }

    /// GHASH known-answer: from NIST GCM test case 2 intermediates.
    /// H = AES_0(0^128) = 66e94bd4ef8a2c3b884cfa59ca342b2e,
    /// GHASH(H, {}, C=0388dace60b6a392f328c2b971b2fe78)
    ///   = f38cbb1ad69223dcc3457ae5b6b0f885.
    #[test]
    fn ghash_known_answer() {
        let h = u128::from_be_bytes([
            0x66, 0xe9, 0x4b, 0xd4, 0xef, 0x8a, 0x2c, 0x3b, 0x88, 0x4c, 0xfa, 0x59, 0xca, 0x34,
            0x2b, 0x2e,
        ]);
        let c: [u8; 16] = [
            0x03, 0x88, 0xda, 0xce, 0x60, 0xb6, 0xa3, 0x92, 0xf3, 0x28, 0xc2, 0xb9, 0x71, 0xb2,
            0xfe, 0x78,
        ];
        let mut g = GhashSoft::new(h);
        g.update(&c);
        g.update_lengths(0, 16);
        let expect: [u8; 16] = [
            0xf3, 0x8c, 0xbb, 0x1a, 0xd6, 0x92, 0x23, 0xdc, 0xc3, 0x45, 0x7a, 0xe5, 0xb6, 0xb0,
            0xf8, 0x85,
        ];
        assert_eq!(g.finalize(), expect);
    }

    #[test]
    fn partial_block_padding_matches_manual_pad() {
        let h = 0x12345678_9abcdef0_0fedcba9_87654321u128;
        let data = [0xaau8; 21]; // 1 full block + 5-byte tail
        let mut a = GhashSoft::new(h);
        a.update(&data);
        let mut padded = [0u8; 32];
        padded[..21].copy_from_slice(&data);
        let mut b = GhashSoft::new(h);
        b.update(&padded);
        assert_eq!(a.finalize(), b.finalize());
    }
}
