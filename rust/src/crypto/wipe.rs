//! Best-effort zeroization of key material.
//!
//! Every key-schedule type in this crate ([`super::aes::AesKey`],
//! [`super::aesni::AesNiKey`], [`super::ghash::GhashTableKey`],
//! [`super::ghash::GhashSoft`], [`super::clmul::GhashClmulKey`]) wipes its
//! backing bytes on `Drop` through these helpers — the `key-hygiene`
//! cryptlint rule ([`crate::analysis`]) enforces that the impls exist.
//!
//! The writes are volatile and followed by a compiler fence so the
//! zeroization cannot be elided as a dead store when the value is about to
//! go out of scope — exactly the case `Drop` runs in. This is best-effort
//! hygiene (copies spilled to registers/stack by earlier computation are
//! out of reach, as is the OS paging the bytes out); the goal is that a
//! key's *owned* storage never outlives the key in process memory.

#![allow(unsafe_code)]

use std::sync::atomic::{compiler_fence, Ordering};

/// Volatile-zero every byte of `v`.
///
/// Crate-private on purpose: overwriting with zeroes is only valid for
/// plain-old-data types (integer/SIMD arrays — everything the crypto key
/// schedules store). Zeroing a type containing references or niches would
/// be instant UB, so this must not be exposed as a safe public API.
pub(crate) fn wipe_value<T: Copy>(v: &mut T) {
    let p = v as *mut T as *mut u8;
    let n = core::mem::size_of::<T>();
    // SAFETY: `p` covers exactly the `n` bytes of a live, exclusively
    // borrowed `T`; byte-wise volatile stores stay in bounds and cannot be
    // elided by the optimizer.
    unsafe {
        for i in 0..n {
            core::ptr::write_volatile(p.add(i), 0);
        }
    }
    compiler_fence(Ordering::SeqCst);
}

/// Volatile-zero a byte slice (subkey seeds, serialized key blocks).
pub fn wipe_bytes(b: &mut [u8]) {
    let p = b.as_mut_ptr();
    // SAFETY: writes stay within the exclusively borrowed slice bounds.
    unsafe {
        for i in 0..b.len() {
            core::ptr::write_volatile(p.add(i), 0);
        }
    }
    compiler_fence(Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::aes::AesKey;
    use crate::crypto::ghash::{GhashSoft, GhashTableKey};

    #[test]
    fn wipe_bytes_zeroes() {
        let mut b = vec![0xA5u8; 77];
        wipe_bytes(&mut b);
        assert!(b.iter().all(|&x| x == 0));
    }

    /// A dropped key schedule's backing memory is cleared. `ManuallyDrop`
    /// keeps the storage alive so the bytes can be inspected after
    /// `drop_in_place` runs the wipe.
    #[test]
    #[cfg_attr(miri, ignore)] // deliberately inspects a dropped value's bytes
    fn aes_key_backing_memory_wiped_on_drop() {
        use core::mem::ManuallyDrop;
        let mut k = ManuallyDrop::new(AesKey::new(&[0xA5u8; 16]));
        assert!(k.rk.iter().any(|&w| w != 0), "schedule starts nonzero");
        // SAFETY: the value is dropped exactly once and never used as an
        // `AesKey` afterwards; the storage itself stays live inside the
        // `ManuallyDrop`, and `u8` reads of it are always valid.
        unsafe {
            core::ptr::drop_in_place(&mut *k as *mut AesKey);
            let p = &*k as *const AesKey as *const u8;
            for i in 0..core::mem::size_of::<AesKey>() {
                assert_eq!(core::ptr::read_volatile(p.add(i)), 0, "byte {i} survived drop");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // deliberately inspects a dropped value's bytes
    fn ghash_table_key_backing_memory_wiped_on_drop() {
        use core::mem::ManuallyDrop;
        let mut k = ManuallyDrop::new(GhashTableKey::new(0x0123_4567_89ab_cdef_u128 << 17));
        // SAFETY: as in `aes_key_backing_memory_wiped_on_drop`.
        unsafe {
            core::ptr::drop_in_place(&mut *k as *mut GhashTableKey);
            let p = &*k as *const GhashTableKey as *const u8;
            for i in 0..core::mem::size_of::<GhashTableKey>() {
                assert_eq!(core::ptr::read_volatile(p.add(i)), 0, "byte {i} survived drop");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // deliberately inspects a dropped value's bytes
    fn ghash_soft_backing_memory_wiped_on_drop() {
        use core::mem::ManuallyDrop;
        let mut g = ManuallyDrop::new(GhashSoft::new(0xdead_beef_u128));
        g.update(&[7u8; 48]);
        // SAFETY: as in `aes_key_backing_memory_wiped_on_drop`.
        unsafe {
            core::ptr::drop_in_place(&mut *g as *mut GhashSoft);
            let p = &*g as *const GhashSoft as *const u8;
            for i in 0..core::mem::size_of::<GhashSoft>() {
                assert_eq!(core::ptr::read_volatile(p.add(i)), 0, "byte {i} survived drop");
            }
        }
    }
}
