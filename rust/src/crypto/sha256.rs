//! SHA-256 (FIPS 180-4) — needed by RSA-OAEP's MGF1 and label hash.

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Incremental SHA-256 hasher.
#[derive(Clone)]
pub struct Sha256 {
    h: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Sha256 { h: H0, buf: [0u8; 64], buf_len: 0, total: 0 }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let need = 64 - self.buf_len;
            let take = need.min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
            if data.is_empty() {
                return;
            }
            // buf was flushed above, or we'd have consumed all of data.
            debug_assert_eq!(self.buf_len, 0);
        }
        let mut chunks = data.chunks_exact(64);
        for block in &mut chunks {
            self.compress(block.try_into().unwrap());
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            self.buf[..rest.len()].copy_from_slice(rest);
            self.buf_len = rest.len();
        }
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        // Padding: 0x80, zeros to ≡56 (mod 64), then the 64-bit bit length.
        let used = (self.total as usize + 1) % 64;
        let zeros = (56 + 64 - used) % 64;
        let mut pad = [0u8; 64 + 9];
        pad[0] = 0x80;
        pad[1 + zeros..9 + zeros].copy_from_slice(&bit_len.to_be_bytes());
        self.update(&pad[..9 + zeros]);
        debug_assert_eq!(self.buf_len, 0);
        let mut out = [0u8; 32];
        for (i, w) in self.h.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for i in 0..16 {
            w[i] = u32::from_be_bytes(block[4 * i..4 * i + 4].try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (i, v) in [a, b, c, d, e, f, g, h].into_iter().enumerate() {
            self.h[i] = self.h[i].wrapping_add(v);
        }
    }
}

/// One-shot SHA-256.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// MGF1 mask generation (PKCS#1 v2.2 §B.2.1) with SHA-256.
pub fn mgf1_sha256(seed: &[u8], out_len: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(out_len);
    let mut counter = 0u32;
    while out.len() < out_len {
        let mut h = Sha256::new();
        h.update(seed);
        h.update(&counter.to_be_bytes());
        out.extend_from_slice(&h.finalize());
        counter += 1;
    }
    out.truncate(out_len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hexs(b: &[u8]) -> String {
        b.iter().map(|x| format!("{x:02x}")).collect()
    }

    #[test]
    fn fips_vectors() {
        assert_eq!(
            hexs(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hexs(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hexs(&sha256(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn million_a() {
        let mut h = Sha256::new();
        let chunk = [b'a'; 1000];
        for _ in 0..1000 {
            h.update(&chunk);
        }
        assert_eq!(
            hexs(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data: Vec<u8> = (0..1000u32).map(|i| i as u8).collect();
        for split in [0usize, 1, 63, 64, 65, 500, 999, 1000] {
            let mut h = Sha256::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), sha256(&data), "split={split}");
        }
    }

    /// RustCrypto `sha2` cross-check, behind the `oracle` feature (the
    /// default build assumes no external crates; the FIPS vectors above
    /// are the always-on correctness anchor).
    #[cfg(feature = "oracle")]
    #[test]
    fn oracle_rustcrypto_sha2() {
        use sha2::Digest;
        let mut st = 3u64;
        for len in [0usize, 1, 55, 56, 57, 63, 64, 65, 127, 128, 1000, 10000] {
            let data: Vec<u8> = (0..len)
                .map(|_| {
                    st ^= st << 13;
                    st ^= st >> 7;
                    st ^= st << 17;
                    st as u8
                })
                .collect();
            let theirs: [u8; 32] = sha2::Sha256::digest(&data).into();
            assert_eq!(sha256(&data), theirs, "len={len}");
        }
    }

    #[test]
    fn mgf1_known_answer() {
        // MGF1-SHA256("bar", 50) from public PKCS#1 examples.
        let out = mgf1_sha256(b"bar", 50);
        assert_eq!(
            hexs(&out),
            "382576a7841021cc28fc4c0948753fb8312090cea942ea4c4e735d10dc724b155f9f6069f289d61daca0cb814502ef04eae1"
        );
    }
}
