//! GHASH via PCLMULQDQ — the hot path on x86-64.
//!
//! Implements the byte-reflected carry-less multiplication of the Intel
//! GCM white paper: blocks are byte-swapped on load, multiplied with a
//! Karatsuba clmul, shifted left one bit, and reduced modulo
//! `x^128 + x^7 + x^2 + x + 1`. Verified against the bit-serial software
//! GHASH in [`super::ghash`].

#![allow(unsafe_code)]

#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

/// Whether the CPU supports PCLMULQDQ (+SSSE3 for the byte shuffle).
pub fn available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("pclmulqdq")
            && std::arch::is_x86_feature_detected!("ssse3")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

#[cfg(target_arch = "x86_64")]
mod imp {
    use super::*;

    #[inline(always)]
    unsafe fn bswap_mask() -> __m128i {
        _mm_set_epi8(0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15)
    }

    /// Karatsuba carry-less multiply WITHOUT reduction: returns the 256-bit
    /// product as (lo, hi). Products are linear, so multiple block·H^k
    /// products can be XOR-aggregated before a single reduction — the
    /// classic 4-block GHASH aggregation (§Perf optimization).
    #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
    unsafe fn clmul_nored(a: __m128i, b: __m128i) -> (__m128i, __m128i) {
        let mut lo = _mm_clmulepi64_si128(a, b, 0x00);
        let mut mid = _mm_clmulepi64_si128(a, b, 0x10);
        let mid2 = _mm_clmulepi64_si128(a, b, 0x01);
        let mut hi = _mm_clmulepi64_si128(a, b, 0x11);
        mid = _mm_xor_si128(mid, mid2);
        lo = _mm_xor_si128(lo, _mm_slli_si128(mid, 8));
        hi = _mm_xor_si128(hi, _mm_srli_si128(mid, 8));
        (lo, hi)
    }

    /// Shift the 256-bit value left one bit and reduce modulo
    /// `x^128 + x^7 + x^2 + x + 1` (byte-reflected domain).
    #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
    unsafe fn shift_reduce(mut tmp3: __m128i, mut tmp6: __m128i) -> __m128i {
        // Shift the 256-bit product [tmp6:tmp3] left by one bit.
        let mut tmp7 = _mm_srli_epi32(tmp3, 31);
        let mut tmp8 = _mm_srli_epi32(tmp6, 31);
        tmp3 = _mm_slli_epi32(tmp3, 1);
        tmp6 = _mm_slli_epi32(tmp6, 1);
        let tmp9 = _mm_srli_si128(tmp7, 12);
        tmp8 = _mm_slli_si128(tmp8, 4);
        tmp7 = _mm_slli_si128(tmp7, 4);
        tmp3 = _mm_or_si128(tmp3, tmp7);
        tmp6 = _mm_or_si128(tmp6, tmp8);
        tmp6 = _mm_or_si128(tmp6, tmp9);

        // Reduce modulo x^128 + x^7 + x^2 + x + 1.
        let mut tmp7 = _mm_slli_epi32(tmp3, 31);
        let tmp8 = _mm_slli_epi32(tmp3, 30);
        let tmp9 = _mm_slli_epi32(tmp3, 25);
        tmp7 = _mm_xor_si128(tmp7, tmp8);
        tmp7 = _mm_xor_si128(tmp7, tmp9);
        let tmp8b = _mm_srli_si128(tmp7, 4);
        tmp7 = _mm_slli_si128(tmp7, 12);
        tmp3 = _mm_xor_si128(tmp3, tmp7);

        let mut tmp2 = _mm_srli_epi32(tmp3, 1);
        let tmp4b = _mm_srli_epi32(tmp3, 2);
        let tmp5c = _mm_srli_epi32(tmp3, 7);
        tmp2 = _mm_xor_si128(tmp2, tmp4b);
        tmp2 = _mm_xor_si128(tmp2, tmp5c);
        tmp2 = _mm_xor_si128(tmp2, tmp8b);
        tmp3 = _mm_xor_si128(tmp3, tmp2);
        _mm_xor_si128(tmp6, tmp3)
    }

    /// Carry-less multiply + reduce (single block).
    #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
    unsafe fn gfmul(a: __m128i, b: __m128i) -> __m128i {
        let (lo, hi) = clmul_nored(a, b);
        shift_reduce(lo, hi)
    }

    /// Incremental GHASH accumulator (CLMUL path) with 4-block aggregated
    /// reduction: Y' = ((Y^C0)·H⁴ ^ C1·H³ ^ C2·H² ^ C3·H) reduced once.
    #[derive(Clone)]
    pub struct GhashClmul {
        /// h_pow[k] = H^(k+1) in the reflected domain.
        h_pow: [__m128i; 4],
        y: __m128i,
    }

    impl GhashClmul {
        /// # Safety
        /// Caller must ensure PCLMULQDQ+SSSE3 are available.
        #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
        pub unsafe fn new(h_block: &[u8; 16]) -> Self {
            let h = _mm_shuffle_epi8(
                _mm_loadu_si128(h_block.as_ptr() as *const __m128i),
                bswap_mask(),
            );
            let h2 = gfmul(h, h);
            let h3 = gfmul(h2, h);
            let h4 = gfmul(h3, h);
            GhashClmul { h_pow: [h, h2, h3, h4], y: _mm_setzero_si128() }
        }

        /// # Safety: see `new`.
        #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
        pub unsafe fn update(&mut self, data: &[u8]) {
            let mask = bswap_mask();
            let [h1, h2, h3, h4] = self.h_pow;
            let mut quads = data.chunks_exact(64);
            for quad in &mut quads {
                let p = quad.as_ptr() as *const __m128i;
                let x0 = _mm_shuffle_epi8(_mm_loadu_si128(p), mask);
                let x1 = _mm_shuffle_epi8(_mm_loadu_si128(p.add(1)), mask);
                let x2 = _mm_shuffle_epi8(_mm_loadu_si128(p.add(2)), mask);
                let x3 = _mm_shuffle_epi8(_mm_loadu_si128(p.add(3)), mask);
                let (l0, hh0) = clmul_nored(_mm_xor_si128(self.y, x0), h4);
                let (l1, hh1) = clmul_nored(x1, h3);
                let (l2, hh2) = clmul_nored(x2, h2);
                let (l3, hh3) = clmul_nored(x3, h1);
                let lo = _mm_xor_si128(_mm_xor_si128(l0, l1), _mm_xor_si128(l2, l3));
                let hi = _mm_xor_si128(_mm_xor_si128(hh0, hh1), _mm_xor_si128(hh2, hh3));
                self.y = shift_reduce(lo, hi);
            }
            let mut chunks = quads.remainder().chunks_exact(16);
            for chunk in &mut chunks {
                let x = _mm_shuffle_epi8(
                    _mm_loadu_si128(chunk.as_ptr() as *const __m128i),
                    mask,
                );
                self.y = gfmul(_mm_xor_si128(self.y, x), h1);
            }
            let rest = chunks.remainder();
            if !rest.is_empty() {
                let mut pad = [0u8; 16];
                pad[..rest.len()].copy_from_slice(rest);
                let x = _mm_shuffle_epi8(
                    _mm_loadu_si128(pad.as_ptr() as *const __m128i),
                    mask,
                );
                self.y = gfmul(_mm_xor_si128(self.y, x), h1);
            }
        }

        /// # Safety: see `new`.
        #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
        pub unsafe fn update_lengths(&mut self, aad_bytes: u64, ct_bytes: u64) {
            let block = _mm_set_epi64x((aad_bytes * 8) as i64, (ct_bytes * 8) as i64);
            self.y = gfmul(_mm_xor_si128(self.y, block), self.h_pow[0]);
        }

        /// # Safety: see `new`.
        #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
        pub unsafe fn finalize(&self) -> [u8; 16] {
            let out = _mm_shuffle_epi8(self.y, bswap_mask());
            let mut b = [0u8; 16];
            _mm_storeu_si128(b.as_mut_ptr() as *mut __m128i, out);
            b
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub use imp::GhashClmul;

#[cfg(all(test, target_arch = "x86_64"))]
mod tests {
    use super::*;
    use crate::crypto::ghash::{block_to_elem, GhashSoft};

    fn rand_bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut st = seed | 1;
        (0..n)
            .map(|_| {
                st ^= st << 13;
                st ^= st >> 7;
                st ^= st << 17;
                st as u8
            })
            .collect()
    }

    #[test]
    fn clmul_matches_soft_ghash() {
        if !available() {
            eprintln!("PCLMULQDQ unavailable; skipping");
            return;
        }
        for (seed, len) in [(1u64, 16usize), (2, 32), (3, 15), (4, 17), (5, 160), (6, 4096), (7, 1)] {
            let h: [u8; 16] = rand_bytes(16, seed * 77)[..].try_into().unwrap();
            let data = rand_bytes(len, seed);
            let mut soft = GhashSoft::new(block_to_elem(&h));
            soft.update(&data);
            soft.update_lengths(0, len as u64);

            unsafe {
                let mut fast = GhashClmul::new(&h);
                fast.update(&data);
                fast.update_lengths(0, len as u64);
                assert_eq!(fast.finalize(), soft.finalize(), "len={len}");
            }
        }
    }

    #[test]
    fn clmul_incremental_chunking_invariance() {
        if !available() {
            return;
        }
        let h: [u8; 16] = rand_bytes(16, 99)[..].try_into().unwrap();
        let data = rand_bytes(256, 123);
        unsafe {
            let mut a = GhashClmul::new(&h);
            a.update(&data);
            let mut b = GhashClmul::new(&h);
            b.update(&data[..64]);
            b.update(&data[64..192]);
            b.update(&data[192..]);
            assert_eq!(a.finalize(), b.finalize());
        }
    }
}
