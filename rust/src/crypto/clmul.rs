//! GHASH via PCLMULQDQ — the hot path on x86-64.
//!
//! Implements the byte-reflected carry-less multiplication of the Intel
//! GCM white paper: blocks are byte-swapped on load, multiplied with a
//! Karatsuba clmul, shifted left one bit, and reduced modulo
//! `x^128 + x^7 + x^2 + x + 1`. Verified against the bit-serial software
//! GHASH in [`super::ghash`].
//!
//! The state is split in two so GCM key setup stays cheap and the fused
//! one-pass kernel can fold ciphertext registers directly:
//!
//! * [`GhashClmulKey`] — per-key material: `H` eagerly (zero multiplies)
//!   and the power table `H¹..H⁸` built lazily on first use of the 8-way
//!   loop, so per-message subkeys that only ever see short segments never
//!   pay the 7-`gfmul` schedule.
//! * [`GhashClmul`] — a borrow-the-key accumulator whose 8-block
//!   [`fold8`](GhashClmul::fold8) performs one aggregated reduction per
//!   128 bytes: `Y' = reduce((Y⊕C₀)·H⁸ ⊕ C₁·H⁷ ⊕ … ⊕ C₇·H¹)`.

#![allow(unsafe_code)]

#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

/// Whether the CPU supports PCLMULQDQ (+SSSE3 for the byte shuffle).
pub fn available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("pclmulqdq")
            && std::arch::is_x86_feature_detected!("ssse3")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

#[cfg(target_arch = "x86_64")]
mod imp {
    use super::*;
    use std::sync::OnceLock;

    // SAFETY: plain SSE2 (always present on x86-64); no memory access.
    #[inline(always)]
    unsafe fn bswap_mask() -> __m128i {
        _mm_set_epi8(0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15)
    }

    /// Karatsuba carry-less multiply WITHOUT reduction: returns the 256-bit
    /// product as (lo, hi). Products are linear, so multiple block·H^k
    /// products can be XOR-aggregated before a single reduction — the
    /// classic 4-block GHASH aggregation (§Perf optimization).
    // SAFETY: callers must hold PCLMULQDQ+SSSE3 (every call site is itself a
    // #[target_feature] fn reached only through `available()`-guarded paths).
    #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
    unsafe fn clmul_nored(a: __m128i, b: __m128i) -> (__m128i, __m128i) {
        let mut lo = _mm_clmulepi64_si128(a, b, 0x00);
        let mut mid = _mm_clmulepi64_si128(a, b, 0x10);
        let mid2 = _mm_clmulepi64_si128(a, b, 0x01);
        let mut hi = _mm_clmulepi64_si128(a, b, 0x11);
        mid = _mm_xor_si128(mid, mid2);
        lo = _mm_xor_si128(lo, _mm_slli_si128(mid, 8));
        hi = _mm_xor_si128(hi, _mm_srli_si128(mid, 8));
        (lo, hi)
    }

    /// Shift the 256-bit value left one bit and reduce modulo
    /// `x^128 + x^7 + x^2 + x + 1` (byte-reflected domain).
    // SAFETY: callers must hold PCLMULQDQ+SSSE3; register-only arithmetic.
    #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
    unsafe fn shift_reduce(mut tmp3: __m128i, mut tmp6: __m128i) -> __m128i {
        // Shift the 256-bit product [tmp6:tmp3] left by one bit.
        let mut tmp7 = _mm_srli_epi32(tmp3, 31);
        let mut tmp8 = _mm_srli_epi32(tmp6, 31);
        tmp3 = _mm_slli_epi32(tmp3, 1);
        tmp6 = _mm_slli_epi32(tmp6, 1);
        let tmp9 = _mm_srli_si128(tmp7, 12);
        tmp8 = _mm_slli_si128(tmp8, 4);
        tmp7 = _mm_slli_si128(tmp7, 4);
        tmp3 = _mm_or_si128(tmp3, tmp7);
        tmp6 = _mm_or_si128(tmp6, tmp8);
        tmp6 = _mm_or_si128(tmp6, tmp9);

        // Reduce modulo x^128 + x^7 + x^2 + x + 1.
        let mut tmp7 = _mm_slli_epi32(tmp3, 31);
        let tmp8 = _mm_slli_epi32(tmp3, 30);
        let tmp9 = _mm_slli_epi32(tmp3, 25);
        tmp7 = _mm_xor_si128(tmp7, tmp8);
        tmp7 = _mm_xor_si128(tmp7, tmp9);
        let tmp8b = _mm_srli_si128(tmp7, 4);
        tmp7 = _mm_slli_si128(tmp7, 12);
        tmp3 = _mm_xor_si128(tmp3, tmp7);

        let mut tmp2 = _mm_srli_epi32(tmp3, 1);
        let tmp4b = _mm_srli_epi32(tmp3, 2);
        let tmp5c = _mm_srli_epi32(tmp3, 7);
        tmp2 = _mm_xor_si128(tmp2, tmp4b);
        tmp2 = _mm_xor_si128(tmp2, tmp5c);
        tmp2 = _mm_xor_si128(tmp2, tmp8b);
        tmp3 = _mm_xor_si128(tmp3, tmp2);
        _mm_xor_si128(tmp6, tmp3)
    }

    /// Carry-less multiply + reduce (single block).
    // SAFETY: callers must hold PCLMULQDQ+SSSE3; register-only arithmetic.
    #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
    unsafe fn gfmul(a: __m128i, b: __m128i) -> __m128i {
        let (lo, hi) = clmul_nored(a, b);
        shift_reduce(lo, hi)
    }

    /// Per-key GHASH material: `H` in the reflected domain plus the
    /// lazily built aggregation powers `H¹..H⁸`.
    ///
    /// Construction does **zero** field multiplies; the 7-`gfmul` power
    /// schedule is paid once, on the first absorb of a ≥128-byte run, and
    /// cached for the key's lifetime (`OnceLock`, so a `Gcm` shared
    /// across worker threads races benignly).
    #[derive(Clone)]
    pub struct GhashClmulKey {
        h1: __m128i,
        pow: OnceLock<[__m128i; 8]>,
    }

    impl GhashClmulKey {
        /// # Safety
        /// Caller must ensure PCLMULQDQ+SSSE3 are available.
        #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
        pub unsafe fn new(h_block: &[u8; 16]) -> Self {
            let h1 = _mm_shuffle_epi8(
                _mm_loadu_si128(h_block.as_ptr() as *const __m128i),
                bswap_mask(),
            );
            GhashClmulKey { h1, pow: OnceLock::new() }
        }

        /// Volatile-wipe `H` and any built power table (also the `Drop`
        /// path; public so tests and rekey paths can zeroize eagerly).
        pub fn wipe(&mut self) {
            crate::crypto::wipe::wipe_value(&mut self.h1);
            if let Some(p) = self.pow.get_mut() {
                crate::crypto::wipe::wipe_value(p);
            }
        }

        /// `pow[k] = H^(k+1)` — built on first call.
        ///
        /// # Safety: see `new`.
        #[inline]
        unsafe fn pow8(&self) -> &[__m128i; 8] {
            self.pow.get_or_init(|| {
                // SAFETY: constructing self required the CPU features.
                unsafe {
                    let mut p = [self.h1; 8];
                    for k in 1..8 {
                        p[k] = gfmul(p[k - 1], self.h1);
                    }
                    p
                }
            })
        }
    }

    impl Drop for GhashClmulKey {
        fn drop(&mut self) {
            self.wipe();
        }
    }

    /// Incremental GHASH accumulator (CLMUL path) borrowing a
    /// [`GhashClmulKey`], with 8-block aggregated reduction:
    /// `Y' = ((Y^C0)·H⁸ ^ C1·H⁷ ^ … ^ C7·H¹)` reduced once per 128 bytes.
    pub struct GhashClmul<'k> {
        key: &'k GhashClmulKey,
        y: __m128i,
    }

    impl<'k> GhashClmul<'k> {
        /// # Safety
        /// Caller must ensure PCLMULQDQ+SSSE3 are available.
        #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
        pub unsafe fn new(key: &'k GhashClmulKey) -> Self {
            GhashClmul { key, y: _mm_setzero_si128() }
        }

        /// Fold 8 blocks already in registers (wire byte order) with one
        /// aggregated reduction — the fused kernel's per-128-byte step.
        ///
        /// # Safety: see `new`.
        #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
        pub unsafe fn fold8(&mut self, blocks: &[__m128i; 8]) {
            let pow = self.key.pow8();
            let mask = bswap_mask();
            let x0 = _mm_shuffle_epi8(blocks[0], mask);
            let (mut lo, mut hi) = clmul_nored(_mm_xor_si128(self.y, x0), pow[7]);
            for i in 1..8 {
                let xi = _mm_shuffle_epi8(blocks[i], mask);
                let (l, h) = clmul_nored(xi, pow[7 - i]);
                lo = _mm_xor_si128(lo, l);
                hi = _mm_xor_si128(hi, h);
            }
            self.y = shift_reduce(lo, hi);
        }

        /// Fold one block already in a register (wire byte order) — the
        /// fused kernel's tail step.
        ///
        /// # Safety: see `new`.
        #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
        pub unsafe fn fold1(&mut self, block: __m128i) {
            let x = _mm_shuffle_epi8(block, bswap_mask());
            self.y = gfmul(_mm_xor_si128(self.y, x), self.key.h1);
        }

        /// # Safety: see `new`.
        #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
        pub unsafe fn update(&mut self, data: &[u8]) {
            let mut octs = data.chunks_exact(128);
            for oct in &mut octs {
                let p = oct.as_ptr() as *const __m128i;
                let blocks: [__m128i; 8] = core::array::from_fn(|i| _mm_loadu_si128(p.add(i)));
                self.fold8(&blocks);
            }
            let mut chunks = octs.remainder().chunks_exact(16);
            for chunk in &mut chunks {
                self.fold1(_mm_loadu_si128(chunk.as_ptr() as *const __m128i));
            }
            let rest = chunks.remainder();
            if !rest.is_empty() {
                let mut pad = [0u8; 16];
                pad[..rest.len()].copy_from_slice(rest);
                self.fold1(_mm_loadu_si128(pad.as_ptr() as *const __m128i));
            }
        }

        /// # Safety: see `new`.
        #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
        pub unsafe fn update_lengths(&mut self, aad_bytes: u64, ct_bytes: u64) {
            let block = _mm_set_epi64x((aad_bytes * 8) as i64, (ct_bytes * 8) as i64);
            self.y = gfmul(_mm_xor_si128(self.y, block), self.key.h1);
        }

        /// # Safety: see `new`.
        #[target_feature(enable = "pclmulqdq", enable = "ssse3")]
        pub unsafe fn finalize(&self) -> [u8; 16] {
            let out = _mm_shuffle_epi8(self.y, bswap_mask());
            let mut b = [0u8; 16];
            _mm_storeu_si128(b.as_mut_ptr() as *mut __m128i, out);
            b
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub use imp::{GhashClmul, GhashClmulKey};

#[cfg(all(test, target_arch = "x86_64"))]
mod tests {
    use super::*;
    use crate::crypto::ghash::{block_to_elem, GhashSoft};

    fn rand_bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut st = seed | 1;
        (0..n)
            .map(|_| {
                st ^= st << 13;
                st ^= st >> 7;
                st ^= st << 17;
                st as u8
            })
            .collect()
    }

    #[test]
    fn clmul_matches_soft_ghash() {
        if !available() {
            eprintln!("PCLMULQDQ unavailable; skipping");
            return;
        }
        // Lengths straddle the 8-wide loop boundary (127/128/129) so both
        // the aggregated fold and the serial tail are exercised.
        for (seed, len) in [
            (1u64, 16usize),
            (2, 32),
            (3, 15),
            (4, 17),
            (5, 127),
            (6, 128),
            (7, 129),
            (8, 160),
            (9, 4096),
            (10, 1),
        ] {
            let h: [u8; 16] = rand_bytes(16, seed * 77)[..].try_into().unwrap();
            let data = rand_bytes(len, seed);
            let mut soft = GhashSoft::new(block_to_elem(&h));
            soft.update(&data);
            soft.update_lengths(0, len as u64);

            // SAFETY: available() was checked at the top of the test.
            unsafe {
                let key = GhashClmulKey::new(&h);
                let mut fast = GhashClmul::new(&key);
                fast.update(&data);
                fast.update_lengths(0, len as u64);
                assert_eq!(fast.finalize(), soft.finalize(), "len={len}");
            }
        }
    }

    #[test]
    fn clmul_incremental_chunking_invariance() {
        if !available() {
            return;
        }
        let h: [u8; 16] = rand_bytes(16, 99)[..].try_into().unwrap();
        let data = rand_bytes(512, 123);
        // SAFETY: available() was checked at the top of the test.
        unsafe {
            let key = GhashClmulKey::new(&h);
            let mut a = GhashClmul::new(&key);
            a.update(&data);
            // A fresh key (powers not yet built) absorbing the same data in
            // ragged pieces — mixing serial and 8-wide folds — must agree.
            let key2 = GhashClmulKey::new(&h);
            let mut b = GhashClmul::new(&key2);
            b.update(&data[..64]);
            b.update(&data[64..192]);
            b.update(&data[192..448]);
            b.update(&data[448..]);
            assert_eq!(a.finalize(), b.finalize());
        }
    }

    /// `wipe()` (the `Drop` path) zeroes both `H` and the lazily built
    /// power table: afterwards every GHASH product is a multiply by zero,
    /// so the accumulator can never leave zero. (The whole-struct byte
    /// check used for the POD schedules lives in `crypto::wipe::tests`;
    /// this key holds a `OnceLock`, so the observable-behavior check is
    /// the right probe.)
    #[test]
    fn clmul_key_wipe_zeroes_material() {
        if !available() {
            return;
        }
        // SAFETY: available() was checked at the top of the test.
        unsafe {
            let mut key = GhashClmulKey::new(&[0x5Au8; 16]);
            let mut g = GhashClmul::new(&key);
            g.update(&[1u8; 256]); // force the H^1..H^8 power table to build
            let pre = g.finalize();
            assert_ne!(pre, [0u8; 16], "live key produces nonzero GHASH");
            drop(g);
            key.wipe();
            let mut g2 = GhashClmul::new(&key);
            g2.update(&[0xFFu8; 256]);
            g2.update_lengths(0, 256);
            assert_eq!(g2.finalize(), [0u8; 16], "wiped key must act as H = 0");
        }
    }
}
