//! RSA-OAEP (PKCS#1 v2.2, SHA-256) — the key-distribution primitive.
//!
//! The paper distributes the two AES master keys `(K1, K2)` at `MPI_Init`
//! by RSA-OAEP-encrypting them under each rank's public key (BoringSSL in
//! the paper; implemented from scratch here on [`super::bignum`]).

use super::bignum::{gen_prime, Bn};
use super::rand::{secure_bytes, ChaChaRng};
use super::sha256::{mgf1_sha256, sha256};

/// Default modulus size for the simulated cluster. 1024-bit keeps key
/// generation fast in tests; [`RsaKeyPair::generate`] accepts any size and
/// the suite also exercises 2048-bit.
pub const DEFAULT_BITS: usize = 1024;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RsaError {
    MessageTooLong,
    Decryption,
}

impl std::fmt::Display for RsaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}
impl std::error::Error for RsaError {}

/// RSA public key.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RsaPublicKey {
    pub n: Bn,
    pub e: Bn,
    /// Modulus length in bytes.
    pub k: usize,
}

/// RSA private key (with CRT components for fast decryption).
#[derive(Clone)]
pub struct RsaPrivateKey {
    pub public: RsaPublicKey,
    d: Bn,
    p: Bn,
    q: Bn,
    dp: Bn,
    dq: Bn,
    qinv: Bn,
}

/// An RSA keypair.
pub struct RsaKeyPair {
    pub public: RsaPublicKey,
    pub private: RsaPrivateKey,
}

impl RsaKeyPair {
    /// Generate a fresh keypair with an `bits`-bit modulus and e = 65537.
    pub fn generate(bits: usize, rng: &mut ChaChaRng) -> Self {
        assert!(bits >= 512 && bits % 2 == 0, "modulus too small");
        let e = Bn::from_u64(65537);
        loop {
            let p = gen_prime(bits / 2, rng);
            let q = gen_prime(bits / 2, rng);
            if p == q {
                continue;
            }
            let n = p.mul(&q);
            if n.bit_len() != bits {
                continue;
            }
            let one = Bn::from_u64(1);
            let p1 = p.sub(&one);
            let q1 = q.sub(&one);
            let phi = p1.mul(&q1);
            // gcd(e, phi) must be 1; mod_inverse returns None otherwise.
            // phi is even, so invert modulo phi via the odd-modulus trick:
            // compute d as inverse of e mod phi using the generic route —
            // phi even breaks binary inversion, so fall back to inverting
            // e mod p-1 related quantities is wrong; instead use the
            // classical extended Euclid on (e, phi) with small e.
            let d = match invert_small_e(65537, &phi) {
                Some(d) => d,
                None => continue,
            };
            let dp = d.mod_reduce(&p1);
            let dq = d.mod_reduce(&q1);
            let qinv = match q.mod_inverse(&p) {
                Some(x) => x,
                None => continue,
            };
            let k = bits / 8;
            let public = RsaPublicKey { n: n.clone(), e: e.clone(), k };
            let private = RsaPrivateKey { public: public.clone(), d, p, q, dp, dq, qinv };
            return RsaKeyPair { public, private };
        }
    }
}

/// Invert a small public exponent modulo (possibly even) phi using the
/// iterative relation `d = (1 + t*phi) / e` searched over t — equivalently
/// the extended Euclid specialized to small `e`: find d with e·d ≡ 1 (mod φ).
fn invert_small_e(e: u64, phi: &Bn) -> Option<Bn> {
    // e is prime (65537); invertible iff phi % e != 0.
    let r = {
        // phi mod e
        let mut acc: u128 = 0;
        for &l in phi.limbs.iter().rev() {
            acc = ((acc << 64) | l as u128) % e as u128;
        }
        acc as u64
    };
    if r == 0 {
        return None;
    }
    // Find t in [1, e) with (1 + t*phi) ≡ 0 (mod e)  ⇒  t ≡ -phi^{-1} (mod e).
    // Compute phi^{-1} mod e with small-int extended Euclid.
    let inv_phi = small_mod_inverse(r, e)?;
    let t = (e - inv_phi) % e;
    let num = Bn::from_u64(1).add(&Bn::from_u64(t).mul(phi));
    // d = num / e (exact division).
    Some(div_exact_small(&num, e))
}

fn small_mod_inverse(a: u64, m: u64) -> Option<u64> {
    let (mut old_r, mut r) = (a as i128, m as i128);
    let (mut old_s, mut s) = (1i128, 0i128);
    while r != 0 {
        let q = old_r / r;
        (old_r, r) = (r, old_r - q * r);
        (old_s, s) = (s, old_s - q * s);
    }
    if old_r != 1 {
        return None;
    }
    Some(old_s.rem_euclid(m as i128) as u64)
}

/// Exact division of a big integer by a small divisor.
fn div_exact_small(n: &Bn, d: u64) -> Bn {
    let mut out = vec![0u64; n.limbs.len()];
    let mut rem: u128 = 0;
    for i in (0..n.limbs.len()).rev() {
        let cur = (rem << 64) | n.limbs[i] as u128;
        out[i] = (cur / d as u128) as u64;
        rem = cur % d as u128;
    }
    assert_eq!(rem, 0, "division was not exact");
    let mut b = Bn { limbs: out };
    while b.limbs.last() == Some(&0) {
        b.limbs.pop();
    }
    b
}

const HLEN: usize = 32; // SHA-256 output size

impl RsaPublicKey {
    /// Maximum OAEP message length for this key. Zero for moduli too
    /// small to carry OAEP-SHA-256 (k < 2·hLen + 2, i.e. below 1024 bits).
    pub fn max_msg_len(&self) -> usize {
        self.k.saturating_sub(2 * HLEN + 2)
    }

    /// RSAES-OAEP encrypt (empty label).
    pub fn encrypt_oaep(&self, msg: &[u8]) -> Result<Vec<u8>, RsaError> {
        if msg.len() > self.max_msg_len() {
            return Err(RsaError::MessageTooLong);
        }
        let k = self.k;
        // EME-OAEP encoding.
        let l_hash = sha256(&[]);
        let db_len = k - HLEN - 1;
        let mut db = vec![0u8; db_len];
        db[..HLEN].copy_from_slice(&l_hash);
        db[db_len - msg.len() - 1] = 0x01;
        db[db_len - msg.len()..].copy_from_slice(msg);
        let mut seed = [0u8; HLEN];
        secure_bytes(&mut seed);
        let db_mask = mgf1_sha256(&seed, db_len);
        for (b, m) in db.iter_mut().zip(db_mask.iter()) {
            *b ^= m;
        }
        let seed_mask = mgf1_sha256(&db, HLEN);
        let mut masked_seed = seed;
        for (b, m) in masked_seed.iter_mut().zip(seed_mask.iter()) {
            *b ^= m;
        }
        let mut em = vec![0u8; k];
        em[1..1 + HLEN].copy_from_slice(&masked_seed);
        em[1 + HLEN..].copy_from_slice(&db);
        // RSA encryption.
        let m = Bn::from_bytes_be(&em);
        let c = m.modpow(&self.e, &self.n);
        Ok(c.to_bytes_be(k))
    }
}

impl RsaPrivateKey {
    /// RSAES-OAEP decrypt (empty label).
    pub fn decrypt_oaep(&self, ct: &[u8]) -> Result<Vec<u8>, RsaError> {
        let k = self.public.k;
        if ct.len() != k {
            return Err(RsaError::Decryption);
        }
        let c = Bn::from_bytes_be(ct);
        if c.cmp_bn(&self.public.n) != std::cmp::Ordering::Less {
            return Err(RsaError::Decryption);
        }
        // CRT decryption: m1 = c^dp mod p, m2 = c^dq mod q,
        // h = qinv (m1 - m2) mod p, m = m2 + h q.
        let m1 = c.mod_reduce(&self.p).modpow(&self.dp, &self.p);
        let m2 = c.mod_reduce(&self.q).modpow(&self.dq, &self.q);
        let diff = m1.add(&self.p).sub(&m2.mod_reduce(&self.p)).mod_reduce(&self.p);
        let h = self.qinv.mul(&diff).mod_reduce(&self.p);
        let m = m2.add(&h.mul(&self.q));
        let em = m.to_bytes_be(k);
        // EME-OAEP decoding.
        if em[0] != 0 {
            return Err(RsaError::Decryption);
        }
        let masked_seed = &em[1..1 + HLEN];
        let masked_db = &em[1 + HLEN..];
        let seed_mask = mgf1_sha256(masked_db, HLEN);
        let seed: Vec<u8> =
            masked_seed.iter().zip(seed_mask.iter()).map(|(a, b)| a ^ b).collect();
        let db_mask = mgf1_sha256(&seed, masked_db.len());
        let db: Vec<u8> = masked_db.iter().zip(db_mask.iter()).map(|(a, b)| a ^ b).collect();
        let l_hash = sha256(&[]);
        if db[..HLEN] != l_hash {
            return Err(RsaError::Decryption);
        }
        // Find the 0x01 separator after the padding string.
        let mut idx = HLEN;
        while idx < db.len() && db[idx] == 0 {
            idx += 1;
        }
        if idx == db.len() || db[idx] != 0x01 {
            return Err(RsaError::Decryption);
        }
        Ok(db[idx + 1..].to_vec())
    }

    /// The plain RSA private exponent (exposed for tests).
    pub fn d(&self) -> &Bn {
        &self.d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_rng(tag: u8) -> ChaChaRng {
        ChaChaRng::from_seed([tag; 32])
    }

    #[test]
    fn keygen_and_textbook_rsa_roundtrip() {
        let mut rng = test_rng(1);
        let kp = RsaKeyPair::generate(512, &mut rng);
        // e*d ≡ 1 (mod phi) implies m^(ed) = m.
        let m = Bn::from_u64(0x1234_5678_9abc_def0);
        let c = m.modpow(&kp.public.e, &kp.public.n);
        let m2 = c.modpow(kp.private.d(), &kp.public.n);
        assert_eq!(m, m2);
    }

    #[test]
    fn oaep_roundtrip_1024() {
        let mut rng = test_rng(2);
        let kp = RsaKeyPair::generate(1024, &mut rng);
        for msg in [b"".as_slice(), b"k", b"two aes keys: k1k1k1k1k1k2k2k2k2", &[0xaau8; 62]] {
            let ct = kp.public.encrypt_oaep(msg).unwrap();
            assert_eq!(ct.len(), 128);
            let pt = kp.private.decrypt_oaep(&ct).unwrap();
            assert_eq!(pt, msg);
        }
    }

    #[test]
    fn oaep_randomized_encryption() {
        let mut rng = test_rng(3);
        let kp = RsaKeyPair::generate(1024, &mut rng);
        let a = kp.public.encrypt_oaep(b"hi").unwrap();
        let b = kp.public.encrypt_oaep(b"hi").unwrap();
        assert_ne!(a, b, "OAEP must be randomized");
        assert_eq!(kp.private.decrypt_oaep(&a).unwrap(), b"hi");
        assert_eq!(kp.private.decrypt_oaep(&b).unwrap(), b"hi");
    }

    #[test]
    fn oaep_rejects_tampering() {
        let mut rng = test_rng(4);
        let kp = RsaKeyPair::generate(1024, &mut rng);
        let ct = kp.public.encrypt_oaep(b"secret keys").unwrap();
        for i in [0usize, 10, 32, 63] {
            let mut bad = ct.clone();
            bad[i] ^= 1;
            assert!(kp.private.decrypt_oaep(&bad).is_err(), "byte {i}");
        }
        assert!(kp.private.decrypt_oaep(&ct[..ct.len() - 1]).is_err());
    }

    #[test]
    fn oaep_message_too_long() {
        let mut rng = test_rng(5);
        let kp = RsaKeyPair::generate(1024, &mut rng);
        let too_long = vec![0u8; kp.public.max_msg_len() + 1];
        assert_eq!(kp.public.encrypt_oaep(&too_long), Err(RsaError::MessageTooLong));
        let ok = vec![0u8; kp.public.max_msg_len()];
        assert!(kp.public.encrypt_oaep(&ok).is_ok());
    }

    #[test]
    fn wrong_key_fails() {
        let mut rng = test_rng(6);
        let kp1 = RsaKeyPair::generate(1024, &mut rng);
        let kp2 = RsaKeyPair::generate(1024, &mut rng);
        let ct = kp1.public.encrypt_oaep(b"for kp1 only").unwrap();
        assert!(kp2.private.decrypt_oaep(&ct).is_err());
    }

    #[test]
    #[ignore = "slow: 2048-bit keygen (~seconds); run with --ignored"]
    fn oaep_roundtrip_2048() {
        let mut rng = test_rng(7);
        let kp = RsaKeyPair::generate(2048, &mut rng);
        let msg = [0x42u8; 32];
        let ct = kp.public.encrypt_oaep(&msg).unwrap();
        assert_eq!(kp.private.decrypt_oaep(&ct).unwrap(), msg);
    }
}
