//! AES-128-GCM authenticated encryption (NIST SP 800-38D).
//!
//! Composes the AES block cipher ([`super::aes`] / [`super::aesni`]) with
//! GHASH ([`super::ghash`] / [`super::clmul`]). Hardware paths (AES-NI +
//! PCLMULQDQ) are selected at key-setup time when the CPU supports them;
//! the portable paths are bit-for-bit equivalent (tested).
//!
//! **Fused one-pass kernels.** [`seal_in_place`](Gcm::seal_in_place) and
//! [`open_in_place`](Gcm::open_in_place) make exactly one pass over the
//! payload: on the hardware path, 8 keystream blocks come out of the
//! AES-NI pipeline, are XORed into the buffer, and the just-produced
//! ciphertext is folded into GHASH with one aggregated reduction per 128
//! bytes — while the data is still in registers. Decrypt fuses the mirror
//! order (hash the ciphertext block, *then* overwrite it with plaintext),
//! and on tag mismatch re-applies the keystream so the buffer is restored
//! to the untouched ciphertext — a forgery never leaves attacker-chosen
//! plaintext behind. The portable path interleaves the T-table CTR 4
//! blocks at a time and hashes through Shoup 4-bit tables. The original
//! two-pass code remains as [`Gcm::seal_in_place_two_pass`] /
//! [`Gcm::open_in_place_two_pass`] — the correctness reference the fused
//! kernels are tested against and the "before" side of the `gcm` bench.
//!
//! Only 12-byte nonces are supported — that is all GCM deployments use in
//! practice and all CryptMPI needs (the paper's Algorithm 1 nonces are
//! `[0]_7 ‖ [last]_1 ‖ [i]_4`, and the small-message path uses random
//! 12-byte nonces).

#![allow(unsafe_code)]

use super::aes::{encrypt_block_soft, encrypt_blocks_soft, AesKey};
use super::aesni;
#[cfg(target_arch = "x86_64")]
use super::clmul;
use super::ghash::{block_to_elem, GhashTable, GhashTableKey};
use std::sync::OnceLock;

/// Byte length of the GCM authentication tag.
pub const TAG_LEN: usize = 16;
/// Byte length of the GCM nonce.
pub const NONCE_LEN: usize = 12;

/// Authenticated-decryption failure. Deliberately carries no detail beyond
/// the failure class: distinguishing *why* a ciphertext was rejected leaks
/// information.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AuthError;

impl std::fmt::Display for AuthError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "GCM authentication failed")
    }
}
impl std::error::Error for AuthError {}

#[cfg(target_arch = "x86_64")]
#[derive(Clone)]
enum Backend {
    /// AES-NI + PCLMULQDQ, with the per-key GHASH power table.
    Hw { ni: aesni::AesNiKey, gk: clmul::GhashClmulKey },
    /// Portable: Shoup 4-bit GHASH tables + interleaved T-table CTR.
    Soft { gt: GhashTableKey },
}

#[cfg(not(target_arch = "x86_64"))]
#[derive(Clone)]
enum Backend {
    Soft { gt: GhashTableKey },
}

/// Whether `CRYPTMPI_SOFT_CRYPTO=1` forces the portable backend. Read from
/// the environment once per process: `stream.rs` builds a fresh subkey
/// `Gcm` per chopped message, and an env lookup per message is measurable.
fn force_soft() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| std::env::var_os("CRYPTMPI_SOFT_CRYPTO").is_some_and(|v| v == "1"))
}

/// An AES-128-GCM key, ready for sealing/opening.
#[derive(Clone)]
pub struct Gcm {
    key: AesKey,
    backend: Backend,
}

impl Gcm {
    /// Derive a GCM context from a 16-byte key. Picks the hardware path if
    /// available unless `CRYPTMPI_SOFT_CRYPTO=1` forces the portable one
    /// (the flag is cached process-wide on first use).
    pub fn new(key_bytes: &[u8; 16]) -> Self {
        Self::with_backend(key_bytes, !force_soft())
    }

    /// Derive a subkey context that inherits `parent`'s backend choice
    /// instead of re-consulting the environment and CPU feature detection.
    /// This is the per-message constructor of the streaming scheme: one
    /// subkey `Gcm` is built per chopped message, so its setup cost is on
    /// the hot path.
    pub fn subkey_like(parent: &Self, key_bytes: &[u8; 16]) -> Self {
        Self::with_backend(key_bytes, parent.is_hw())
    }

    /// Explicit backend choice (used by tests and the Bridges crypto
    /// profile, which models a slower node with software crypto).
    pub fn with_backend(key_bytes: &[u8; 16], allow_hw: bool) -> Self {
        let key = AesKey::new(key_bytes);
        // Hash subkey H = AES_K(0^128).
        let mut h_block = [0u8; 16];
        encrypt_block_soft(&key, &mut h_block);
        #[cfg(target_arch = "x86_64")]
        let backend = if allow_hw && aesni::available() && clmul::available() {
            Backend::Hw {
                ni: aesni::AesNiKey::from_schedule(&key),
                // SAFETY: clmul::available() just held.
                gk: unsafe { clmul::GhashClmulKey::new(&h_block) },
            }
        } else {
            Backend::Soft { gt: GhashTableKey::new(block_to_elem(&h_block)) }
        };
        #[cfg(not(target_arch = "x86_64"))]
        let backend = {
            let _ = allow_hw;
            Backend::Soft { gt: GhashTableKey::new(block_to_elem(&h_block)) }
        };
        Gcm { key, backend }
    }

    /// Whether this context uses the hardware path.
    pub fn is_hw(&self) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            matches!(self.backend, Backend::Hw { .. })
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    }

    /// The portable GHASH table key (panics on the hardware backend).
    fn soft_table(&self) -> &GhashTableKey {
        match &self.backend {
            #[cfg(target_arch = "x86_64")]
            Backend::Hw { .. } => unreachable!("soft_table on hardware backend"),
            Backend::Soft { gt } => gt,
        }
    }

    /// Raw AES forward permutation under this key — used by the streaming
    /// scheme's subkey derivation `L = AES_K(V)` (paper Algorithm 1 line 4).
    pub fn aes_encrypt_block(&self, block: &mut [u8; 16]) {
        #[cfg(target_arch = "x86_64")]
        if let Backend::Hw { ni, .. } = &self.backend {
            // SAFETY: Hw variant only constructed when AES-NI is available.
            unsafe { ni.encrypt_block(block) };
            return;
        }
        encrypt_block_soft(&self.key, block);
    }

    #[inline]
    fn j0(nonce: &[u8; NONCE_LEN]) -> [u8; 16] {
        let mut j0 = [0u8; 16];
        j0[..12].copy_from_slice(nonce);
        j0[15] = 1;
        j0
    }

    /// Four consecutive CTR keystream blocks (`counter .. counter+3`)
    /// through the interleaved T-table path — the portable sweep step
    /// shared by the fused kernels and the two-pass/restore pass.
    fn soft_keystream4(&self, j0: &[u8; 16], counter: u32) -> [[u8; 16]; 4] {
        let mut ks = [[0u8; 16]; 4];
        for (i, blk) in ks.iter_mut().enumerate() {
            *blk = *j0;
            blk[12..16].copy_from_slice(&counter.wrapping_add(i as u32).to_be_bytes());
        }
        encrypt_blocks_soft(&self.key, &mut ks);
        ks
    }

    /// One CTR keystream block (portable tail step).
    fn soft_keystream1(&self, j0: &[u8; 16], counter: u32) -> [u8; 16] {
        let mut blk = *j0;
        blk[12..16].copy_from_slice(&counter.to_be_bytes());
        encrypt_block_soft(&self.key, &mut blk);
        blk
    }

    /// Lengths block, final GHASH output, tag mask `E_K(J0)` — the shared
    /// tail of both portable fused kernels (mirrors `fused_hw::finish_tag`).
    fn soft_finish_tag(
        &self,
        g: &mut GhashTable<'_>,
        j0: &[u8; 16],
        aad: usize,
        ct: usize,
    ) -> [u8; 16] {
        let mut s = g.finalize_tag(aad as u64, ct as u64);
        let mut ek_j0 = *j0;
        encrypt_block_soft(&self.key, &mut ek_j0);
        for (t, m) in s.iter_mut().zip(ek_j0.iter()) {
            *t ^= m;
        }
        s
    }

    /// CTR-mode transform starting at counter value `ctr` of `J0`'s counter
    /// field (GCM data starts at 2; `1` is reserved for the tag mask).
    /// This is the keystream pass of the two-pass reference path — and the
    /// restore pass of a failed fused open.
    fn ctr_xor(&self, j0: &[u8; 16], ctr: u32, data: &mut [u8]) {
        #[cfg(target_arch = "x86_64")]
        if let Backend::Hw { ni, .. } = &self.backend {
            // SAFETY: Hw variant only constructed when AES-NI is available.
            unsafe { ni.ctr_xor(j0, ctr, data) };
            return;
        }
        let mut counter = ctr;
        let mut chunks = data.chunks_exact_mut(64);
        for chunk in &mut chunks {
            let ks = self.soft_keystream4(j0, counter);
            counter = counter.wrapping_add(4);
            for (seg, blk) in chunk.chunks_exact_mut(16).zip(ks.iter()) {
                for (b, k) in seg.iter_mut().zip(blk.iter()) {
                    *b ^= k;
                }
            }
        }
        for chunk in chunks.into_remainder().chunks_mut(16) {
            let blk = self.soft_keystream1(j0, counter);
            counter = counter.wrapping_add(1);
            for (b, k) in chunk.iter_mut().zip(blk.iter()) {
                *b ^= k;
            }
        }
    }

    /// GHASH(A, C) ‖ lengths, dispatching to CLMUL or the 4-bit tables.
    fn ghash(&self, aad: &[u8], ct: &[u8]) -> [u8; 16] {
        #[cfg(target_arch = "x86_64")]
        if let Backend::Hw { gk, .. } = &self.backend {
            // SAFETY: Hw implies clmul::available() held at construction.
            unsafe {
                let mut g = clmul::GhashClmul::new(gk);
                g.update(aad);
                g.update(ct);
                g.update_lengths(aad.len() as u64, ct.len() as u64);
                return g.finalize();
            }
        }
        let mut g = GhashTable::new(self.soft_table());
        g.update(aad);
        g.update(ct);
        g.update_lengths(aad.len() as u64, ct.len() as u64);
        g.finalize()
    }

    fn tag(&self, j0: &[u8; 16], aad: &[u8], ct: &[u8]) -> [u8; 16] {
        let mut s = self.ghash(aad, ct);
        let mut ek_j0 = *j0;
        self.aes_encrypt_block(&mut ek_j0);
        for (t, m) in s.iter_mut().zip(ek_j0.iter()) {
            *t ^= m;
        }
        s
    }

    /// Encrypt `plaintext` in place and return the 16-byte tag.
    ///
    /// This is the zero-copy hot-path primitive: the coordinator encrypts
    /// segment buffers in place and appends the tag itself. It runs the
    /// fused one-pass kernel: CTR keystream generation, the XOR into the
    /// buffer, and the GHASH fold over the resulting ciphertext happen in
    /// a single sweep (bit-for-bit equal to
    /// [`seal_in_place_two_pass`](Self::seal_in_place_two_pass), tested).
    pub fn seal_in_place(
        &self,
        nonce: &[u8; NONCE_LEN],
        aad: &[u8],
        data: &mut [u8],
    ) -> [u8; 16] {
        let j0 = Self::j0(nonce);
        #[cfg(target_arch = "x86_64")]
        if let Backend::Hw { ni, gk } = &self.backend {
            // SAFETY: Hw variant implies AES-NI + PCLMULQDQ + SSSE3.
            return unsafe { fused_hw::seal(ni, gk, &j0, aad, data) };
        }
        self.seal_fused_soft(&j0, aad, data)
    }

    /// Decrypt `data` (ciphertext without tag) in place after verifying
    /// `tag`. Runs the fused one-pass kernel in hash-then-decrypt order:
    /// each ciphertext block is folded into GHASH *before* it is
    /// overwritten with plaintext. If the tag does not verify, the
    /// keystream is re-applied so the buffer again holds the untouched
    /// ciphertext — a tampered message never yields attacker-controlled
    /// plaintext to the caller (same observable behaviour as the two-pass
    /// verify-before-decrypt reference).
    pub fn open_in_place(
        &self,
        nonce: &[u8; NONCE_LEN],
        aad: &[u8],
        data: &mut [u8],
        tag: &[u8; TAG_LEN],
    ) -> Result<(), AuthError> {
        let j0 = Self::j0(nonce);
        #[cfg(target_arch = "x86_64")]
        let expect = if let Backend::Hw { ni, gk } = &self.backend {
            // SAFETY: Hw variant implies AES-NI + PCLMULQDQ + SSSE3.
            unsafe { fused_hw::open_tag(ni, gk, &j0, aad, data) }
        } else {
            self.open_fused_soft(&j0, aad, data)
        };
        #[cfg(not(target_arch = "x86_64"))]
        let expect = self.open_fused_soft(&j0, aad, data);
        if !ct_eq(&expect, tag) {
            // Restore: XOR the keystream back so the buffer holds the
            // original ciphertext, exactly as if it had never been touched.
            self.ctr_xor(&j0, 2, data);
            return Err(AuthError);
        }
        Ok(())
    }

    /// Fused portable seal: 4 interleaved T-table CTR blocks per sweep
    /// step, each ciphertext block folded into the 4-bit-table GHASH as
    /// it is produced.
    fn seal_fused_soft(&self, j0: &[u8; 16], aad: &[u8], data: &mut [u8]) -> [u8; 16] {
        let gt = self.soft_table();
        let mut g = GhashTable::new(gt);
        g.update(aad);
        let mut counter = 2u32;
        let total = data.len();
        let mut chunks = data.chunks_exact_mut(64);
        for chunk in &mut chunks {
            let ks = self.soft_keystream4(j0, counter);
            counter = counter.wrapping_add(4);
            for (seg, blk) in chunk.chunks_exact_mut(16).zip(ks.iter()) {
                let mut ct = [0u8; 16];
                for (c, (b, k)) in ct.iter_mut().zip(seg.iter().zip(blk.iter())) {
                    *c = b ^ k;
                }
                seg.copy_from_slice(&ct);
                g.absorb_block(&ct);
            }
        }
        for chunk in chunks.into_remainder().chunks_mut(16) {
            let blk = self.soft_keystream1(j0, counter);
            counter = counter.wrapping_add(1);
            for (b, k) in chunk.iter_mut().zip(blk.iter()) {
                *b ^= k;
            }
            g.update(chunk);
        }
        self.soft_finish_tag(&mut g, j0, aad.len(), total)
    }

    /// Fused portable open: mirror order — fold each ciphertext block into
    /// GHASH, then overwrite it with plaintext. Returns the expected tag;
    /// the caller compares and restores on mismatch.
    fn open_fused_soft(&self, j0: &[u8; 16], aad: &[u8], data: &mut [u8]) -> [u8; 16] {
        let gt = self.soft_table();
        let mut g = GhashTable::new(gt);
        g.update(aad);
        let mut counter = 2u32;
        let total = data.len();
        let mut chunks = data.chunks_exact_mut(64);
        for chunk in &mut chunks {
            let ks = self.soft_keystream4(j0, counter);
            counter = counter.wrapping_add(4);
            for (seg, blk) in chunk.chunks_exact_mut(16).zip(ks.iter()) {
                g.absorb_block(seg[..].try_into().unwrap());
                for (b, k) in seg.iter_mut().zip(blk.iter()) {
                    *b ^= k;
                }
            }
        }
        for chunk in chunks.into_remainder().chunks_mut(16) {
            g.update(chunk);
            let blk = self.soft_keystream1(j0, counter);
            counter = counter.wrapping_add(1);
            for (b, k) in chunk.iter_mut().zip(blk.iter()) {
                *b ^= k;
            }
        }
        self.soft_finish_tag(&mut g, j0, aad.len(), total)
    }

    /// The original two-pass seal (CTR sweep, then a separate GHASH
    /// sweep). Kept as the correctness reference for the fused kernel and
    /// as the "before" side of the `gcm` bench runner.
    pub fn seal_in_place_two_pass(
        &self,
        nonce: &[u8; NONCE_LEN],
        aad: &[u8],
        data: &mut [u8],
    ) -> [u8; 16] {
        let j0 = Self::j0(nonce);
        self.ctr_xor(&j0, 2, data);
        self.tag(&j0, aad, data)
    }

    /// The original two-pass open: verify the tag over the ciphertext,
    /// then decrypt. See [`seal_in_place_two_pass`](Self::seal_in_place_two_pass).
    pub fn open_in_place_two_pass(
        &self,
        nonce: &[u8; NONCE_LEN],
        aad: &[u8],
        data: &mut [u8],
        tag: &[u8; TAG_LEN],
    ) -> Result<(), AuthError> {
        let j0 = Self::j0(nonce);
        let expect = self.tag(&j0, aad, data);
        if !ct_eq(&expect, tag) {
            return Err(AuthError);
        }
        self.ctr_xor(&j0, 2, data);
        Ok(())
    }

    /// Convenience: allocate-and-seal, returning `ciphertext ‖ tag`.
    pub fn seal(&self, nonce: &[u8; NONCE_LEN], aad: &[u8], plaintext: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(plaintext.len() + TAG_LEN);
        out.extend_from_slice(plaintext);
        let tag = self.seal_in_place(nonce, aad, &mut out[..]);
        out.extend_from_slice(&tag);
        out
    }

    /// Convenience: verify-and-open `ciphertext ‖ tag`.
    pub fn open(
        &self,
        nonce: &[u8; NONCE_LEN],
        aad: &[u8],
        ct_and_tag: &[u8],
    ) -> Result<Vec<u8>, AuthError> {
        if ct_and_tag.len() < TAG_LEN {
            return Err(AuthError);
        }
        let split = ct_and_tag.len() - TAG_LEN;
        let mut data = ct_and_tag[..split].to_vec();
        let tag: [u8; TAG_LEN] = ct_and_tag[split..].try_into().unwrap();
        self.open_in_place(nonce, aad, &mut data, &tag)?;
        Ok(data)
    }
}

/// The fused one-pass hardware kernel: 8-block AES-NI CTR interleave with
/// the ciphertext folded into the 8-way aggregated CLMUL GHASH while the
/// blocks are still in registers. One load and one store per payload block
/// — the buffer is traversed exactly once.
#[cfg(target_arch = "x86_64")]
mod fused_hw {
    use super::super::aesni::{self, AesNiKey};
    use super::super::clmul::{GhashClmul, GhashClmulKey};
    use core::arch::x86_64::*;

    /// Seal: keystream → XOR (plaintext becomes ciphertext) → fold.
    ///
    /// # Safety
    /// Caller must ensure AES-NI, PCLMULQDQ and SSSE3 are available.
    #[target_feature(enable = "aes", enable = "pclmulqdq", enable = "ssse3", enable = "sse2")]
    pub unsafe fn seal(
        ni: &AesNiKey,
        gk: &GhashClmulKey,
        j0: &[u8; 16],
        aad: &[u8],
        data: &mut [u8],
    ) -> [u8; 16] {
        let mut g = GhashClmul::new(gk);
        g.update(aad);
        let prefix = aesni::ctr_prefix(j0);
        let mut counter = 2u32;
        let total = data.len();
        let mut chunks = data.chunks_exact_mut(128);
        for chunk in &mut chunks {
            let ks = ni.keystream8(prefix, counter);
            counter = counter.wrapping_add(8);
            let mut ct = [_mm_setzero_si128(); 8];
            for (i, (slot, k)) in ct.iter_mut().zip(ks.iter()).enumerate() {
                let p = chunk.as_mut_ptr().add(16 * i) as *mut __m128i;
                let c = _mm_xor_si128(_mm_loadu_si128(p), *k);
                _mm_storeu_si128(p, c);
                *slot = c;
            }
            g.fold8(&ct);
        }
        let rest = chunks.into_remainder();
        for part in rest.chunks_mut(16) {
            let ks = ni.keystream1(prefix, counter);
            counter = counter.wrapping_add(1);
            let mut ksb = [0u8; 16];
            _mm_storeu_si128(ksb.as_mut_ptr() as *mut __m128i, ks);
            let mut pad = [0u8; 16];
            for (j, byte) in part.iter_mut().enumerate() {
                *byte ^= ksb[j];
                pad[j] = *byte;
            }
            g.fold1(_mm_loadu_si128(pad.as_ptr() as *const __m128i));
        }
        finish_tag(ni, &mut g, j0, aad.len() as u64, total as u64)
    }

    /// Open: fold the ciphertext block, *then* overwrite it with
    /// plaintext — the mirror order that keeps the single pass sound when
    /// hashing and decrypting in place. Returns the expected tag; the
    /// caller compares (and restores the buffer on mismatch).
    ///
    /// # Safety
    /// Caller must ensure AES-NI, PCLMULQDQ and SSSE3 are available.
    #[target_feature(enable = "aes", enable = "pclmulqdq", enable = "ssse3", enable = "sse2")]
    pub unsafe fn open_tag(
        ni: &AesNiKey,
        gk: &GhashClmulKey,
        j0: &[u8; 16],
        aad: &[u8],
        data: &mut [u8],
    ) -> [u8; 16] {
        let mut g = GhashClmul::new(gk);
        g.update(aad);
        let prefix = aesni::ctr_prefix(j0);
        let mut counter = 2u32;
        let total = data.len();
        let mut chunks = data.chunks_exact_mut(128);
        for chunk in &mut chunks {
            let p = chunk.as_mut_ptr() as *mut __m128i;
            let ct: [__m128i; 8] = core::array::from_fn(|i| _mm_loadu_si128(p.add(i)));
            g.fold8(&ct);
            let ks = ni.keystream8(prefix, counter);
            counter = counter.wrapping_add(8);
            for (i, (c, k)) in ct.iter().zip(ks.iter()).enumerate() {
                _mm_storeu_si128(p.add(i), _mm_xor_si128(*c, *k));
            }
        }
        let rest = chunks.into_remainder();
        for part in rest.chunks_mut(16) {
            let mut pad = [0u8; 16];
            pad[..part.len()].copy_from_slice(part);
            g.fold1(_mm_loadu_si128(pad.as_ptr() as *const __m128i));
            let ks = ni.keystream1(prefix, counter);
            counter = counter.wrapping_add(1);
            let mut ksb = [0u8; 16];
            _mm_storeu_si128(ksb.as_mut_ptr() as *mut __m128i, ks);
            for (j, byte) in part.iter_mut().enumerate() {
                *byte ^= ksb[j];
            }
        }
        finish_tag(ni, &mut g, j0, aad.len() as u64, total as u64)
    }

    /// Lengths block, final GHASH output, tag mask `E_K(J0)`.
    ///
    /// # Safety
    /// Caller must ensure AES-NI, PCLMULQDQ and SSSE3 are available.
    #[target_feature(enable = "aes", enable = "pclmulqdq", enable = "ssse3", enable = "sse2")]
    unsafe fn finish_tag(
        ni: &AesNiKey,
        g: &mut GhashClmul<'_>,
        j0: &[u8; 16],
        aad_bytes: u64,
        ct_bytes: u64,
    ) -> [u8; 16] {
        g.update_lengths(aad_bytes, ct_bytes);
        let mut tag = g.finalize();
        let mut ek_j0 = *j0;
        ni.encrypt_block(&mut ek_j0);
        for (t, m) in tag.iter_mut().zip(ek_j0.iter()) {
            *t ^= m;
        }
        tag
    }
}

/// Constant-time 16-byte comparison.
#[inline]
pub fn ct_eq(a: &[u8; 16], b: &[u8; 16]) -> bool {
    let mut diff = 0u8;
    for i in 0..16 {
        diff |= a[i] ^ b[i];
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(s: &str) -> Vec<u8> {
        (0..s.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
            .collect()
    }

    struct Tv {
        key: &'static str,
        iv: &'static str,
        pt: &'static str,
        aad: &'static str,
        ct: &'static str,
        tag: &'static str,
    }

    /// NIST GCM-spec test cases 1–4 (AES-128).
    const VECTORS: &[Tv] = &[
        Tv {
            key: "00000000000000000000000000000000",
            iv: "000000000000000000000000",
            pt: "",
            aad: "",
            ct: "",
            tag: "58e2fccefa7e3061367f1d57a4e7455a",
        },
        Tv {
            key: "00000000000000000000000000000000",
            iv: "000000000000000000000000",
            pt: "00000000000000000000000000000000",
            aad: "",
            ct: "0388dace60b6a392f328c2b971b2fe78",
            tag: "ab6e47d42cec13bdf53a67b21257bddf",
        },
        Tv {
            key: "feffe9928665731c6d6a8f9467308308",
            iv: "cafebabefacedbaddecaf888",
            pt: "d9313225f88406e5a55909c5aff5269a86a7a9531534f7da2e4c303d8a318a721c3c0c95956809532fcf0e2449a6b525b16aedf5aa0de657ba637b391aafd255",
            aad: "",
            ct: "42831ec2217774244b7221b784d0d49ce3aa212f2c02a4e035c17e2329aca12e21d514b25466931c7d8f6a5aac84aa051ba30b396a0aac973d58e091473f5985",
            tag: "4d5c2af327cd64a62cf35abd2ba6fab4",
        },
        Tv {
            key: "feffe9928665731c6d6a8f9467308308",
            iv: "cafebabefacedbaddecaf888",
            pt: "d9313225f88406e5a55909c5aff5269a86a7a9531534f7da2e4c303d8a318a721c3c0c95956809532fcf0e2449a6b525b16aedf5aa0de657ba637b39",
            aad: "feedfacedeadbeeffeedfacedeadbeefabaddad2",
            ct: "42831ec2217774244b7221b784d0d49ce3aa212f2c02a4e035c17e2329aca12e21d514b25466931c7d8f6a5aac84aa051ba30b396a0aac973d58e091",
            tag: "5bc94fbc3221a5db94fae95ae7121a47",
        },
    ];

    /// The awkward payload shapes the fused kernels must handle: empty,
    /// sub-block, block-aligned, one past, both sides of the 64-byte
    /// (portable 4-wide) and 128-byte (hardware 8-wide) sweep widths, and
    /// a segment larger than the paper's 512 KB chopping size.
    const AWKWARD_LENS: &[usize] =
        &[0, 1, 15, 16, 17, 63, 64, 65, 100, 127, 128, 129, 1024, 65536, 520 * 1024 + 7];

    fn check_vectors(hw: bool) {
        for (i, tv) in VECTORS.iter().enumerate() {
            let key: [u8; 16] = hex(tv.key)[..].try_into().unwrap();
            let nonce: [u8; 12] = hex(tv.iv)[..].try_into().unwrap();
            let gcm = Gcm::with_backend(&key, hw);
            if hw && !gcm.is_hw() {
                eprintln!("hardware crypto unavailable; skipping");
                return;
            }
            let (pt, aad) = (hex(tv.pt), hex(tv.aad));
            let sealed = gcm.seal(&nonce, &aad, &pt);
            assert_eq!(sealed[..pt.len()], hex(tv.ct)[..], "tc{i} ct (hw={hw})");
            assert_eq!(sealed[pt.len()..], hex(tv.tag)[..], "tc{i} tag (hw={hw})");
            let opened = gcm.open(&nonce, &aad, &sealed).expect("valid ct must open");
            assert_eq!(opened, pt, "tc{i} roundtrip");
            // The two-pass reference must hit the same known answers.
            let mut buf = pt.clone();
            let tag = gcm.seal_in_place_two_pass(&nonce, &aad, &mut buf);
            assert_eq!(buf[..], hex(tv.ct)[..], "tc{i} two-pass ct");
            assert_eq!(tag[..], hex(tv.tag)[..], "tc{i} two-pass tag");
            gcm.open_in_place_two_pass(&nonce, &aad, &mut buf, &tag).expect("two-pass open");
            assert_eq!(buf, pt, "tc{i} two-pass roundtrip");
        }
    }

    #[test]
    fn nist_vectors_soft() {
        check_vectors(false);
    }

    #[test]
    fn nist_vectors_hw() {
        check_vectors(true);
    }

    fn xorshift_bytes(len: usize, st: &mut u64) -> Vec<u8> {
        (0..len)
            .map(|_| {
                *st ^= *st << 13;
                *st ^= *st >> 7;
                *st ^= *st << 17;
                *st as u8
            })
            .collect()
    }

    #[test]
    fn hw_and_soft_agree_on_random_messages() {
        let key = [0x3cu8; 16];
        let hw = Gcm::with_backend(&key, true);
        let soft = Gcm::with_backend(&key, false);
        if !hw.is_hw() {
            return;
        }
        let mut st = 7u64;
        for &len in AWKWARD_LENS {
            let data = xorshift_bytes(len, &mut st);
            let nonce = [9u8; 12];
            assert_eq!(hw.seal(&nonce, b"aad", &data), soft.seal(&nonce, b"aad", &data), "len={len}");
        }
    }

    /// Property: on both backends, the fused one-pass kernels are
    /// bit-for-bit equivalent to the two-pass reference — same ciphertext,
    /// same tag, same accepted plaintext — across every awkward shape and
    /// varying AAD lengths.
    #[test]
    fn fused_matches_two_pass_reference() {
        let mut st = 0xfeedu64;
        for hw in [true, false] {
            let gcm = Gcm::with_backend(&[0x77u8; 16], hw);
            if hw && !gcm.is_hw() {
                continue;
            }
            for (i, &len) in AWKWARD_LENS.iter().enumerate() {
                let pt = xorshift_bytes(len, &mut st);
                let aad = xorshift_bytes(i * 7 % 40, &mut st);
                let nonce: [u8; 12] = xorshift_bytes(12, &mut st)[..].try_into().unwrap();

                let mut fused = pt.clone();
                let tag_fused = gcm.seal_in_place(&nonce, &aad, &mut fused);
                let mut twopass = pt.clone();
                let tag_two = gcm.seal_in_place_two_pass(&nonce, &aad, &mut twopass);
                assert_eq!(fused, twopass, "ct hw={hw} len={len}");
                assert_eq!(tag_fused, tag_two, "tag hw={hw} len={len}");

                gcm.open_in_place(&nonce, &aad, &mut fused, &tag_fused).expect("fused open");
                assert_eq!(fused, pt, "fused roundtrip hw={hw} len={len}");
                gcm.open_in_place_two_pass(&nonce, &aad, &mut twopass, &tag_two)
                    .expect("two-pass open");
                assert_eq!(twopass, pt, "two-pass roundtrip hw={hw} len={len}");
            }
        }
    }

    /// A failed fused open must restore the buffer to the untouched
    /// ciphertext (the same observable state the verify-before-decrypt
    /// two-pass reference leaves behind) — never attacker-chosen plaintext.
    #[test]
    fn failed_open_restores_ciphertext() {
        for hw in [true, false] {
            let gcm = Gcm::with_backend(&[0x55u8; 16], hw);
            if hw && !gcm.is_hw() {
                continue;
            }
            for len in [1usize, 16, 65, 129, 1000] {
                let nonce = [3u8; 12];
                let pt = vec![0xc3u8; len];
                let mut buf = pt.clone();
                let mut tag = gcm.seal_in_place(&nonce, b"a", &mut buf);
                let ct = buf.clone();
                tag[0] ^= 1;
                assert!(gcm.open_in_place(&nonce, b"a", &mut buf, &tag).is_err());
                assert_eq!(buf, ct, "must restore ciphertext (hw={hw} len={len})");
            }
        }
    }

    /// `subkey_like` inherits the parent's backend and produces the same
    /// bytes as an explicitly constructed context for that backend.
    #[test]
    fn subkey_like_inherits_backend() {
        let sub_key = [0x42u8; 16];
        let nonce = [1u8; 12];
        for hw in [true, false] {
            let parent = Gcm::with_backend(&[0x10u8; 16], hw);
            let sub = Gcm::subkey_like(&parent, &sub_key);
            assert_eq!(sub.is_hw(), parent.is_hw(), "backend must be inherited");
            let explicit = Gcm::with_backend(&sub_key, hw);
            assert_eq!(
                sub.seal(&nonce, b"", b"subkey message"),
                explicit.seal(&nonce, b"", b"subkey message")
            );
        }
    }

    #[test]
    fn tamper_detection() {
        let gcm = Gcm::new(&[1u8; 16]);
        let nonce = [2u8; 12];
        let sealed = gcm.seal(&nonce, b"", b"attack at dawn!!");
        // Flip each byte in turn (ciphertext and tag): all must fail.
        for i in 0..sealed.len() {
            let mut bad = sealed.clone();
            bad[i] ^= 1;
            assert!(gcm.open(&nonce, b"", &bad).is_err(), "byte {i} tamper undetected");
        }
        // Wrong nonce and wrong AAD must fail too.
        assert!(gcm.open(&[3u8; 12], b"", &sealed).is_err());
        assert!(gcm.open(&nonce, b"x", &sealed).is_err());
        // Truncation must fail.
        assert!(gcm.open(&nonce, b"", &sealed[..sealed.len() - 1]).is_err());
        assert!(gcm.open(&nonce, b"", &[]).is_err());
    }

    #[test]
    fn in_place_matches_vec_api() {
        let gcm = Gcm::new(&[5u8; 16]);
        let nonce = [6u8; 12];
        let msg = vec![0xabu8; 333];
        let sealed = gcm.seal(&nonce, b"hdr", &msg);
        let mut buf = msg.clone();
        let tag = gcm.seal_in_place(&nonce, b"hdr", &mut buf);
        assert_eq!(&sealed[..333], &buf[..]);
        assert_eq!(&sealed[333..], &tag);
        gcm.open_in_place(&nonce, b"hdr", &mut buf, &tag).unwrap();
        assert_eq!(buf, msg);
    }

    #[test]
    fn oracle_cross_check_distinct_keys_distinct_ct() {
        let a = Gcm::new(&[0u8; 16]);
        let b = Gcm::new(&[1u8; 16]);
        let nonce = [0u8; 12];
        assert_ne!(a.seal(&nonce, b"", b"same message"), b.seal(&nonce, b"", b"same message"));
    }
}
