//! AES-128-GCM authenticated encryption (NIST SP 800-38D).
//!
//! Composes the AES block cipher ([`super::aes`] / [`super::aesni`]) with
//! GHASH ([`super::ghash`] / [`super::clmul`]). Hardware paths (AES-NI +
//! PCLMULQDQ) are selected at key-setup time when the CPU supports them;
//! the portable paths are bit-for-bit equivalent (tested).
//!
//! Only 12-byte nonces are supported — that is all GCM deployments use in
//! practice and all CryptMPI needs (the paper's Algorithm 1 nonces are
//! `[0]_7 ‖ [last]_1 ‖ [i]_4`, and the small-message path uses random
//! 12-byte nonces).

use super::aes::{encrypt_block_soft, AesKey};
use super::aesni;
use super::clmul;
use super::ghash::{block_to_elem, GhashSoft};

/// Byte length of the GCM authentication tag.
pub const TAG_LEN: usize = 16;
/// Byte length of the GCM nonce.
pub const NONCE_LEN: usize = 12;

/// Authenticated-decryption failure. Deliberately carries no detail beyond
/// the failure class: distinguishing *why* a ciphertext was rejected leaks
/// information.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AuthError;

impl std::fmt::Display for AuthError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "GCM authentication failed")
    }
}
impl std::error::Error for AuthError {}

#[cfg(target_arch = "x86_64")]
#[derive(Clone)]
enum Backend {
    /// AES-NI + PCLMULQDQ.
    Hw(aesni::AesNiKey),
    /// Portable.
    Soft,
}

#[cfg(not(target_arch = "x86_64"))]
#[derive(Clone)]
enum Backend {
    Soft,
}

/// An AES-128-GCM key, ready for sealing/opening.
#[derive(Clone)]
pub struct Gcm {
    key: AesKey,
    /// Hash subkey `H = AES_K(0^128)` as a field element (soft GHASH form).
    h: u128,
    /// `H` as raw bytes (CLMUL form).
    h_block: [u8; 16],
    backend: Backend,
}

impl Gcm {
    /// Derive a GCM context from a 16-byte key. Picks the hardware path if
    /// available unless `CRYPTMPI_SOFT_CRYPTO=1` forces the portable one.
    pub fn new(key_bytes: &[u8; 16]) -> Self {
        let force_soft = std::env::var_os("CRYPTMPI_SOFT_CRYPTO").is_some_and(|v| v == "1");
        Self::with_backend(key_bytes, !force_soft)
    }

    /// Explicit backend choice (used by tests and the Bridges crypto
    /// profile, which models a slower node with software crypto).
    pub fn with_backend(key_bytes: &[u8; 16], allow_hw: bool) -> Self {
        let key = AesKey::new(key_bytes);
        let mut h_block = [0u8; 16];
        encrypt_block_soft(&key, &mut h_block);
        let h = block_to_elem(&h_block);
        #[cfg(target_arch = "x86_64")]
        let backend = if allow_hw && aesni::available() && clmul::available() {
            Backend::Hw(aesni::AesNiKey::from_schedule(&key))
        } else {
            Backend::Soft
        };
        #[cfg(not(target_arch = "x86_64"))]
        let backend = {
            let _ = allow_hw;
            Backend::Soft
        };
        Gcm { key, h, h_block, backend }
    }

    /// Whether this context uses the hardware path.
    pub fn is_hw(&self) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            matches!(self.backend, Backend::Hw(_))
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    }

    /// Raw AES forward permutation under this key — used by the streaming
    /// scheme's subkey derivation `L = AES_K(V)` (paper Algorithm 1 line 4).
    pub fn aes_encrypt_block(&self, block: &mut [u8; 16]) {
        #[cfg(target_arch = "x86_64")]
        if let Backend::Hw(ni) = &self.backend {
            // SAFETY: Hw variant only constructed when AES-NI is available.
            unsafe { ni.encrypt_block(block) };
            return;
        }
        encrypt_block_soft(&self.key, block);
    }

    #[inline]
    fn j0(nonce: &[u8; NONCE_LEN]) -> [u8; 16] {
        let mut j0 = [0u8; 16];
        j0[..12].copy_from_slice(nonce);
        j0[15] = 1;
        j0
    }

    /// CTR-mode transform starting at counter value `ctr` of `J0`'s counter
    /// field (GCM data starts at 2; `1` is reserved for the tag mask).
    fn ctr_xor(&self, j0: &[u8; 16], ctr: u32, data: &mut [u8]) {
        #[cfg(target_arch = "x86_64")]
        if let Backend::Hw(ni) = &self.backend {
            // SAFETY: Hw variant only constructed when AES-NI is available.
            unsafe { ni.ctr_xor(j0, ctr, data) };
            return;
        }
        let mut counter = ctr;
        for chunk in data.chunks_mut(16) {
            let mut blk = *j0;
            blk[12..16].copy_from_slice(&counter.to_be_bytes());
            counter = counter.wrapping_add(1);
            encrypt_block_soft(&self.key, &mut blk);
            for (b, k) in chunk.iter_mut().zip(blk.iter()) {
                *b ^= k;
            }
        }
    }

    /// GHASH(A, C) ‖ lengths, dispatching to CLMUL or soft.
    fn ghash(&self, aad: &[u8], ct: &[u8]) -> [u8; 16] {
        #[cfg(target_arch = "x86_64")]
        if matches!(self.backend, Backend::Hw(_)) {
            // SAFETY: Hw implies clmul::available() held at construction.
            unsafe {
                let mut g = clmul::GhashClmul::new(&self.h_block);
                g.update(aad);
                g.update(ct);
                g.update_lengths(aad.len() as u64, ct.len() as u64);
                return g.finalize();
            }
        }
        let mut g = GhashSoft::new(self.h);
        g.update(aad);
        g.update(ct);
        g.update_lengths(aad.len() as u64, ct.len() as u64);
        g.finalize()
    }

    fn tag(&self, j0: &[u8; 16], aad: &[u8], ct: &[u8]) -> [u8; 16] {
        let mut s = self.ghash(aad, ct);
        let mut ek_j0 = *j0;
        self.aes_encrypt_block(&mut ek_j0);
        for (t, m) in s.iter_mut().zip(ek_j0.iter()) {
            *t ^= m;
        }
        s
    }

    /// Encrypt `plaintext` in place and return the 16-byte tag.
    ///
    /// This is the zero-copy hot-path primitive: the coordinator encrypts
    /// segment buffers in place and appends the tag itself.
    pub fn seal_in_place(
        &self,
        nonce: &[u8; NONCE_LEN],
        aad: &[u8],
        data: &mut [u8],
    ) -> [u8; 16] {
        let j0 = Self::j0(nonce);
        self.ctr_xor(&j0, 2, data);
        self.tag(&j0, aad, data)
    }

    /// Decrypt `data` (ciphertext without tag) in place after verifying
    /// `tag`. On failure the buffer is left *undecrypted garbage-free*:
    /// the tag is checked over the ciphertext before any decryption, so a
    /// tampered message never yields attacker-controlled plaintext.
    pub fn open_in_place(
        &self,
        nonce: &[u8; NONCE_LEN],
        aad: &[u8],
        data: &mut [u8],
        tag: &[u8; TAG_LEN],
    ) -> Result<(), AuthError> {
        let j0 = Self::j0(nonce);
        let expect = self.tag(&j0, aad, data);
        if !ct_eq(&expect, tag) {
            return Err(AuthError);
        }
        self.ctr_xor(&j0, 2, data);
        Ok(())
    }

    /// Convenience: allocate-and-seal, returning `ciphertext ‖ tag`.
    pub fn seal(&self, nonce: &[u8; NONCE_LEN], aad: &[u8], plaintext: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(plaintext.len() + TAG_LEN);
        out.extend_from_slice(plaintext);
        let tag = self.seal_in_place(nonce, aad, &mut out[..]);
        out.extend_from_slice(&tag);
        out
    }

    /// Convenience: verify-and-open `ciphertext ‖ tag`.
    pub fn open(
        &self,
        nonce: &[u8; NONCE_LEN],
        aad: &[u8],
        ct_and_tag: &[u8],
    ) -> Result<Vec<u8>, AuthError> {
        if ct_and_tag.len() < TAG_LEN {
            return Err(AuthError);
        }
        let split = ct_and_tag.len() - TAG_LEN;
        let mut data = ct_and_tag[..split].to_vec();
        let tag: [u8; TAG_LEN] = ct_and_tag[split..].try_into().unwrap();
        self.open_in_place(nonce, aad, &mut data, &tag)?;
        Ok(data)
    }
}

/// Constant-time 16-byte comparison.
#[inline]
pub fn ct_eq(a: &[u8; 16], b: &[u8; 16]) -> bool {
    let mut diff = 0u8;
    for i in 0..16 {
        diff |= a[i] ^ b[i];
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(s: &str) -> Vec<u8> {
        (0..s.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
            .collect()
    }

    struct Tv {
        key: &'static str,
        iv: &'static str,
        pt: &'static str,
        aad: &'static str,
        ct: &'static str,
        tag: &'static str,
    }

    /// NIST GCM-spec test cases 1–4 (AES-128).
    const VECTORS: &[Tv] = &[
        Tv {
            key: "00000000000000000000000000000000",
            iv: "000000000000000000000000",
            pt: "",
            aad: "",
            ct: "",
            tag: "58e2fccefa7e3061367f1d57a4e7455a",
        },
        Tv {
            key: "00000000000000000000000000000000",
            iv: "000000000000000000000000",
            pt: "00000000000000000000000000000000",
            aad: "",
            ct: "0388dace60b6a392f328c2b971b2fe78",
            tag: "ab6e47d42cec13bdf53a67b21257bddf",
        },
        Tv {
            key: "feffe9928665731c6d6a8f9467308308",
            iv: "cafebabefacedbaddecaf888",
            pt: "d9313225f88406e5a55909c5aff5269a86a7a9531534f7da2e4c303d8a318a721c3c0c95956809532fcf0e2449a6b525b16aedf5aa0de657ba637b391aafd255",
            aad: "",
            ct: "42831ec2217774244b7221b784d0d49ce3aa212f2c02a4e035c17e2329aca12e21d514b25466931c7d8f6a5aac84aa051ba30b396a0aac973d58e091473f5985",
            tag: "4d5c2af327cd64a62cf35abd2ba6fab4",
        },
        Tv {
            key: "feffe9928665731c6d6a8f9467308308",
            iv: "cafebabefacedbaddecaf888",
            pt: "d9313225f88406e5a55909c5aff5269a86a7a9531534f7da2e4c303d8a318a721c3c0c95956809532fcf0e2449a6b525b16aedf5aa0de657ba637b39",
            aad: "feedfacedeadbeeffeedfacedeadbeefabaddad2",
            ct: "42831ec2217774244b7221b784d0d49ce3aa212f2c02a4e035c17e2329aca12e21d514b25466931c7d8f6a5aac84aa051ba30b396a0aac973d58e091",
            tag: "5bc94fbc3221a5db94fae95ae7121a47",
        },
    ];

    fn check_vectors(hw: bool) {
        for (i, tv) in VECTORS.iter().enumerate() {
            let key: [u8; 16] = hex(tv.key)[..].try_into().unwrap();
            let nonce: [u8; 12] = hex(tv.iv)[..].try_into().unwrap();
            let gcm = Gcm::with_backend(&key, hw);
            if hw && !gcm.is_hw() {
                eprintln!("hardware crypto unavailable; skipping");
                return;
            }
            let (pt, aad) = (hex(tv.pt), hex(tv.aad));
            let sealed = gcm.seal(&nonce, &aad, &pt);
            assert_eq!(sealed[..pt.len()], hex(tv.ct)[..], "tc{i} ct (hw={hw})");
            assert_eq!(sealed[pt.len()..], hex(tv.tag)[..], "tc{i} tag (hw={hw})");
            let opened = gcm.open(&nonce, &aad, &sealed).expect("valid ct must open");
            assert_eq!(opened, pt, "tc{i} roundtrip");
        }
    }

    #[test]
    fn nist_vectors_soft() {
        check_vectors(false);
    }

    #[test]
    fn nist_vectors_hw() {
        check_vectors(true);
    }

    #[test]
    fn hw_and_soft_agree_on_random_messages() {
        let key = [0x3cu8; 16];
        let hw = Gcm::with_backend(&key, true);
        let soft = Gcm::with_backend(&key, false);
        if !hw.is_hw() {
            return;
        }
        let mut st = 7u64;
        for len in [0usize, 1, 15, 16, 17, 100, 1024, 65536] {
            let data: Vec<u8> = (0..len)
                .map(|_| {
                    st ^= st << 13;
                    st ^= st >> 7;
                    st ^= st << 17;
                    st as u8
                })
                .collect();
            let nonce = [9u8; 12];
            assert_eq!(hw.seal(&nonce, b"aad", &data), soft.seal(&nonce, b"aad", &data), "len={len}");
        }
    }

    #[test]
    fn tamper_detection() {
        let gcm = Gcm::new(&[1u8; 16]);
        let nonce = [2u8; 12];
        let sealed = gcm.seal(&nonce, b"", b"attack at dawn!!");
        // Flip each byte in turn (ciphertext and tag): all must fail.
        for i in 0..sealed.len() {
            let mut bad = sealed.clone();
            bad[i] ^= 1;
            assert!(gcm.open(&nonce, b"", &bad).is_err(), "byte {i} tamper undetected");
        }
        // Wrong nonce and wrong AAD must fail too.
        assert!(gcm.open(&[3u8; 12], b"", &sealed).is_err());
        assert!(gcm.open(&nonce, b"x", &sealed).is_err());
        // Truncation must fail.
        assert!(gcm.open(&nonce, b"", &sealed[..sealed.len() - 1]).is_err());
        assert!(gcm.open(&nonce, b"", &[]).is_err());
    }

    #[test]
    fn in_place_matches_vec_api() {
        let gcm = Gcm::new(&[5u8; 16]);
        let nonce = [6u8; 12];
        let msg = vec![0xabu8; 333];
        let sealed = gcm.seal(&nonce, b"hdr", &msg);
        let mut buf = msg.clone();
        let tag = gcm.seal_in_place(&nonce, b"hdr", &mut buf);
        assert_eq!(&sealed[..333], &buf[..]);
        assert_eq!(&sealed[333..], &tag);
        gcm.open_in_place(&nonce, b"hdr", &mut buf, &tag).unwrap();
        assert_eq!(buf, msg);
    }

    #[test]
    fn oracle_cross_check_distinct_keys_distinct_ct() {
        let a = Gcm::new(&[0u8; 16]);
        let b = Gcm::new(&[1u8; 16]);
        let nonce = [0u8; 12];
        assert_ne!(a.seal(&nonce, b"", b"same message"), b.seal(&nonce, b"", b"same message"));
    }
}
