//! AES-128 via x86-64 AES-NI intrinsics — the hot path.
//!
//! Mirrors the paper's use of hardware AES (Intel AES-NI) in BoringSSL.
//! Besides single-block encryption, this module exposes wide counter-mode
//! keystream generation (`ctr_xor`) that interleaves 8 independent blocks
//! through the AES round pipeline, which is where almost all encrypted-MPI
//! cycles go.
//!
//! Safety: every function checks (via the cached [`available`] flag read by
//! callers in `gcm.rs`) that the `aes` feature is present before the unsafe
//! intrinsics run.

#![allow(unsafe_code)]

#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

use super::aes::AesKey;

/// Whether the CPU supports AES-NI (+SSE2, which x86-64 always has).
pub fn available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("aes")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

#[cfg(target_arch = "x86_64")]
#[derive(Clone)]
pub struct AesNiKey {
    rk: [__m128i; 11],
}

#[cfg(target_arch = "x86_64")]
impl AesNiKey {
    /// Build from an already-expanded software key schedule. The schedule
    /// bytes are identical between the soft and NI representations, so we
    /// reuse `AesKey`'s expansion (tested against FIPS-197) instead of the
    /// AESKEYGENASSIST dance.
    pub fn from_schedule(key: &AesKey) -> Self {
        // SAFETY: loadu has no alignment requirement; plain SSE2.
        unsafe {
            let mut rk = [_mm_setzero_si128(); 11];
            for (r, slot) in rk.iter_mut().enumerate() {
                let b = key.round_key_bytes(r);
                *slot = _mm_loadu_si128(b.as_ptr() as *const __m128i);
            }
            AesNiKey { rk }
        }
    }

    /// Encrypt a single block.
    ///
    /// # Safety
    /// Caller must ensure AES-NI is available.
    #[target_feature(enable = "aes")]
    pub unsafe fn encrypt_block(&self, block: &mut [u8; 16]) {
        let mut b = _mm_loadu_si128(block.as_ptr() as *const __m128i);
        b = _mm_xor_si128(b, self.rk[0]);
        for r in 1..10 {
            b = _mm_aesenc_si128(b, self.rk[r]);
        }
        b = _mm_aesenclast_si128(b, self.rk[10]);
        _mm_storeu_si128(block.as_mut_ptr() as *mut __m128i, b);
    }

    /// Eight consecutive CTR keystream blocks (`counter .. counter+7`),
    /// interleaved through the AESENC pipeline and returned in registers —
    /// the fused GCM kernel XORs these into the payload and folds the
    /// resulting ciphertext into GHASH without a second pass.
    ///
    /// # Safety
    /// Caller must ensure AES-NI is available.
    #[target_feature(enable = "aes", enable = "sse2")]
    pub unsafe fn keystream8(&self, prefix: __m128i, counter: u32) -> [__m128i; 8] {
        let rk = &self.rk;
        let mut b: [__m128i; 8] =
            core::array::from_fn(|i| ctr_block(prefix, counter.wrapping_add(i as u32)));
        for x in b.iter_mut() {
            *x = _mm_xor_si128(*x, rk[0]);
        }
        for r in 1..10 {
            for x in b.iter_mut() {
                *x = _mm_aesenc_si128(*x, rk[r]);
            }
        }
        for x in b.iter_mut() {
            *x = _mm_aesenclast_si128(*x, rk[10]);
        }
        b
    }

    /// One CTR keystream block (tail path of the fused kernel).
    ///
    /// # Safety
    /// Caller must ensure AES-NI is available.
    #[target_feature(enable = "aes", enable = "sse2")]
    pub unsafe fn keystream1(&self, prefix: __m128i, counter: u32) -> __m128i {
        let rk = &self.rk;
        let mut ks = _mm_xor_si128(ctr_block(prefix, counter), rk[0]);
        for r in 1..10 {
            ks = _mm_aesenc_si128(ks, rk[r]);
        }
        _mm_aesenclast_si128(ks, rk[10])
    }

    /// CTR-mode keystream XOR: `data ^= AES-CTR(counter_block, ...)`.
    ///
    /// `ctr0` is the first 16-byte counter block; the low 32 bits (bytes
    /// 12..16, big-endian per SP 800-38D inc32) increment per block.
    /// Processes 8 blocks per iteration to fill the AESENC pipeline. This
    /// is the keystream pass of the *two-pass reference* path; the fused
    /// kernel in `gcm.rs` drives [`keystream8`](Self::keystream8) itself.
    ///
    /// # Safety
    /// Caller must ensure AES-NI is available.
    #[target_feature(enable = "aes", enable = "sse2")]
    pub unsafe fn ctr_xor(&self, ctr0: &[u8; 16], mut counter: u32, data: &mut [u8]) {
        let prefix = ctr_prefix(ctr0);
        let mut chunks = data.chunks_exact_mut(128);
        for chunk in &mut chunks {
            let b = self.keystream8(prefix, counter);
            counter = counter.wrapping_add(8);
            for (i, x) in b.iter().enumerate() {
                let p = chunk.as_mut_ptr().add(16 * i) as *mut __m128i;
                _mm_storeu_si128(p, _mm_xor_si128(_mm_loadu_si128(p), *x));
            }
        }
        let rest = chunks.into_remainder();
        if !rest.is_empty() {
            let nblocks = rest.len().div_ceil(16);
            for i in 0..nblocks {
                let ks = self.keystream1(prefix, counter);
                counter = counter.wrapping_add(1);
                let mut ksb = [0u8; 16];
                _mm_storeu_si128(ksb.as_mut_ptr() as *mut __m128i, ks);
                let start = 16 * i;
                let end = rest.len().min(start + 16);
                for (j, byte) in rest[start..end].iter_mut().enumerate() {
                    *byte ^= ksb[j];
                }
            }
        }
    }
}

/// The invariant 96 bits of a counter block (`J0` with the low-32 counter
/// field masked out); [`ctr_block`] splices per-block counters back in.
///
/// # Safety
/// Caller must ensure SSE2 intrinsics are safe to use (always on x86-64).
#[cfg(target_arch = "x86_64")]
#[inline]
pub unsafe fn ctr_prefix(ctr0: &[u8; 16]) -> __m128i {
    let base = _mm_loadu_si128(ctr0.as_ptr() as *const __m128i);
    // Counter bytes are big-endian in positions 12..16.
    _mm_and_si128(base, _mm_set_epi32(0, -1, -1, -1))
}

/// One counter block: prefix ‖ big-endian `ctr`.
///
/// # Safety
/// Caller must ensure SSE2 intrinsics are safe to use (always on x86-64).
#[cfg(target_arch = "x86_64")]
#[inline]
pub unsafe fn ctr_block(prefix: __m128i, ctr: u32) -> __m128i {
    _mm_or_si128(prefix, _mm_set_epi32(ctr.swap_bytes() as i32, 0, 0, 0))
}

#[cfg(target_arch = "x86_64")]
impl Drop for AesNiKey {
    /// Volatile-wipe the register-format schedule (see
    /// [`crate::crypto::wipe`]).
    fn drop(&mut self) {
        crate::crypto::wipe::wipe_value(&mut self.rk);
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[derive(Clone)]
pub struct AesNiKey;

#[cfg(all(test, target_arch = "x86_64"))]
mod tests {
    use super::*;
    use crate::crypto::aes::{encrypt_block_soft, AesKey};

    #[test]
    fn ni_matches_soft_single_block() {
        if !available() {
            eprintln!("AES-NI unavailable; skipping");
            return;
        }
        let key = AesKey::new(&[7u8; 16]);
        let ni = AesNiKey::from_schedule(&key);
        for s in 0..64u8 {
            let mut a: [u8; 16] = core::array::from_fn(|i| s.wrapping_add(i as u8 * 17));
            let mut b = a;
            encrypt_block_soft(&key, &mut a);
            // SAFETY: available() was checked at the top of the test.
            unsafe { ni.encrypt_block(&mut b) };
            assert_eq!(a, b);
        }
    }

    #[test]
    fn ctr_xor_matches_block_by_block() {
        if !available() {
            return;
        }
        let key = AesKey::new(&[0x42u8; 16]);
        let ni = AesNiKey::from_schedule(&key);
        let mut ctr0 = [0u8; 16];
        ctr0[..12].copy_from_slice(b"unique-nonce");
        // Reference: encrypt counter blocks one at a time with the soft path.
        for len in [1usize, 15, 16, 17, 127, 128, 129, 1000] {
            let data: Vec<u8> = (0..len).map(|i| i as u8).collect();
            let mut fast = data.clone();
            // SAFETY: available() was checked at the top of the test.
            unsafe { ni.ctr_xor(&ctr0, 2, &mut fast) };

            let mut slow = data.clone();
            for (bi, chunk) in slow.chunks_mut(16).enumerate() {
                let mut blk = ctr0;
                blk[12..16].copy_from_slice(&(2u32 + bi as u32).to_be_bytes());
                encrypt_block_soft(&key, &mut blk);
                for (j, byte) in chunk.iter_mut().enumerate() {
                    *byte ^= blk[j];
                }
            }
            assert_eq!(fast, slow, "len={len}");
        }
    }

    #[test]
    fn ctr_counter_wraps() {
        if !available() {
            return;
        }
        let key = AesKey::new(&[1u8; 16]);
        let ni = AesNiKey::from_schedule(&key);
        let ctr0 = [0x31u8; 16];
        let mut a = vec![0u8; 64];
        // SAFETY: available() was checked at the top of the test.
        unsafe { ni.ctr_xor(&ctr0, u32::MAX - 1, &mut a) };
        let mut b = vec![0u8; 64];
        for (bi, chunk) in b.chunks_mut(16).enumerate() {
            let mut blk = ctr0;
            blk[12..16].copy_from_slice(&(u32::MAX - 1).wrapping_add(bi as u32).to_be_bytes());
            encrypt_block_soft(&key, &mut blk);
            for (j, byte) in chunk.iter_mut().enumerate() {
                *byte ^= blk[j];
            }
        }
        assert_eq!(a, b);
    }
}
