//! AES-128 block cipher — portable software implementation.
//!
//! This is the fallback path used when the host lacks AES-NI and the
//! reference against which the AES-NI path ([`super::aesni`]) is tested.
//! Table-based (T-tables for encryption), matching FIPS-197. Only the
//! encryption direction is needed by GCM/CTR, but decryption is provided
//! for completeness and for the round-trip tests.

/// Number of rounds for AES-128.
pub const ROUNDS: usize = 10;
/// Block size in bytes.
pub const BLOCK: usize = 16;

/// The AES S-box.
pub static SBOX: [u8; 256] = [
    0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b, 0xfe, 0xd7, 0xab, 0x76,
    0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0, 0xad, 0xd4, 0xa2, 0xaf, 0x9c, 0xa4, 0x72, 0xc0,
    0xb7, 0xfd, 0x93, 0x26, 0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5, 0xe5, 0xf1, 0x71, 0xd8, 0x31, 0x15,
    0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a, 0x07, 0x12, 0x80, 0xe2, 0xeb, 0x27, 0xb2, 0x75,
    0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e, 0x5a, 0xa0, 0x52, 0x3b, 0xd6, 0xb3, 0x29, 0xe3, 0x2f, 0x84,
    0x53, 0xd1, 0x00, 0xed, 0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb, 0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf,
    0xd0, 0xef, 0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45, 0xf9, 0x02, 0x7f, 0x50, 0x3c, 0x9f, 0xa8,
    0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5, 0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff, 0xf3, 0xd2,
    0xcd, 0x0c, 0x13, 0xec, 0x5f, 0x97, 0x44, 0x17, 0xc4, 0xa7, 0x7e, 0x3d, 0x64, 0x5d, 0x19, 0x73,
    0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a, 0x90, 0x88, 0x46, 0xee, 0xb8, 0x14, 0xde, 0x5e, 0x0b, 0xdb,
    0xe0, 0x32, 0x3a, 0x0a, 0x49, 0x06, 0x24, 0x5c, 0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79,
    0xe7, 0xc8, 0x37, 0x6d, 0x8d, 0xd5, 0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08,
    0xba, 0x78, 0x25, 0x2e, 0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f, 0x4b, 0xbd, 0x8b, 0x8a,
    0x70, 0x3e, 0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e, 0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e,
    0xe1, 0xf8, 0x98, 0x11, 0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55, 0x28, 0xdf,
    0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f, 0xb0, 0x54, 0xbb, 0x16,
];

/// Inverse S-box (for decryption).
pub static INV_SBOX: [u8; 256] = {
    let mut inv = [0u8; 256];
    let mut i = 0;
    while i < 256 {
        inv[SBOX[i] as usize] = i as u8;
        i += 1;
    }
    inv
};

/// Round constants for the AES-128 key schedule.
const RCON: [u8; 10] = [0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1b, 0x36];

/// GF(2^8) multiply by 2 (xtime).
#[inline(always)]
const fn xtime(b: u8) -> u8 {
    (b << 1) ^ (((b >> 7) & 1) * 0x1b)
}

/// GF(2^8) multiplication (used by decryption's InvMixColumns and tests).
pub const fn gf_mul(mut a: u8, mut b: u8) -> u8 {
    let mut p = 0u8;
    let mut i = 0;
    while i < 8 {
        if b & 1 != 0 {
            p ^= a;
        }
        a = xtime(a);
        b >>= 1;
        i += 1;
    }
    p
}

/// T-table: `TE0[x] = (S[x]*2, S[x], S[x], S[x]*3)` packed little-endian-ish
/// as a u32; the other three tables are byte rotations. Built at compile
/// time.
static TE0: [u32; 256] = {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let s = SBOX[i];
        let s2 = xtime(s);
        let s3 = s2 ^ s;
        t[i] = (s2 as u32) | ((s as u32) << 8) | ((s as u32) << 16) | ((s3 as u32) << 24);
        i += 1;
    }
    t
};

#[inline(always)]
fn te(i: u8, rot: u32) -> u32 {
    TE0[i as usize].rotate_left(rot * 8)
}

/// Expanded AES-128 key schedule: 11 round keys of 16 bytes.
#[derive(Clone)]
pub struct AesKey {
    /// Round keys as 44 little-endian u32 words (FIPS-197 column order).
    pub rk: [u32; 4 * (ROUNDS + 1)],
}

impl AesKey {
    /// Expand a 16-byte AES-128 key.
    pub fn new(key: &[u8; 16]) -> Self {
        let mut rk = [0u32; 44];
        for (i, w) in rk.iter_mut().take(4).enumerate() {
            *w = u32::from_le_bytes([key[4 * i], key[4 * i + 1], key[4 * i + 2], key[4 * i + 3]]);
        }
        for i in 4..44 {
            let mut temp = rk[i - 1];
            if i % 4 == 0 {
                // RotWord + SubWord + Rcon (little-endian word layout).
                temp = temp.rotate_right(8);
                let b = temp.to_le_bytes();
                temp = u32::from_le_bytes([
                    SBOX[b[0] as usize],
                    SBOX[b[1] as usize],
                    SBOX[b[2] as usize],
                    SBOX[b[3] as usize],
                ]);
                temp ^= RCON[i / 4 - 1] as u32;
            }
            rk[i] = rk[i - 4] ^ temp;
        }
        AesKey { rk }
    }

    /// Round key `r` as 16 bytes (for the AES-NI path and tests).
    pub fn round_key_bytes(&self, r: usize) -> [u8; 16] {
        let mut out = [0u8; 16];
        for c in 0..4 {
            out[4 * c..4 * c + 4].copy_from_slice(&self.rk[4 * r + c].to_le_bytes());
        }
        out
    }
}

impl Drop for AesKey {
    /// Volatile-wipe the expanded schedule so round keys never outlive the
    /// key in process memory (see [`super::wipe`]).
    fn drop(&mut self) {
        crate::crypto::wipe::wipe_value(&mut self.rk);
    }
}

/// Encrypt one 16-byte block in place (software T-table path).
pub fn encrypt_block_soft(key: &AesKey, block: &mut [u8; 16]) {
    encrypt_blocks_soft(key, core::array::from_mut(block));
}

/// Encrypt `N` independent 16-byte blocks in place, with the round loop
/// interleaved across blocks: each round's T-table lookups for all `N`
/// states are independent, so the compiler can overlap their L1 latencies
/// instead of serializing one block's 40-lookup chain. The fused GCM
/// kernel runs this 4 wide as the portable CTR keystream generator.
pub fn encrypt_blocks_soft<const N: usize>(key: &AesKey, blocks: &mut [[u8; 16]; N]) {
    let rk = &key.rk;
    let mut s = [[0u32; 4]; N];
    for (st, b) in s.iter_mut().zip(blocks.iter()) {
        for c in 0..4 {
            st[c] = u32::from_le_bytes([b[4 * c], b[4 * c + 1], b[4 * c + 2], b[4 * c + 3]])
                ^ rk[c];
        }
    }

    for r in 1..ROUNDS {
        for st in s.iter_mut() {
            let [s0, s1, s2, s3] = *st;
            let t0 = te(s0 as u8, 0)
                ^ te((s1 >> 8) as u8, 1)
                ^ te((s2 >> 16) as u8, 2)
                ^ te((s3 >> 24) as u8, 3)
                ^ rk[4 * r];
            let t1 = te(s1 as u8, 0)
                ^ te((s2 >> 8) as u8, 1)
                ^ te((s3 >> 16) as u8, 2)
                ^ te((s0 >> 24) as u8, 3)
                ^ rk[4 * r + 1];
            let t2 = te(s2 as u8, 0)
                ^ te((s3 >> 8) as u8, 1)
                ^ te((s0 >> 16) as u8, 2)
                ^ te((s1 >> 24) as u8, 3)
                ^ rk[4 * r + 2];
            let t3 = te(s3 as u8, 0)
                ^ te((s0 >> 8) as u8, 1)
                ^ te((s1 >> 16) as u8, 2)
                ^ te((s2 >> 24) as u8, 3)
                ^ rk[4 * r + 3];
            *st = [t0, t1, t2, t3];
        }
    }

    // Final round: SubBytes + ShiftRows + AddRoundKey (no MixColumns).
    let f = |a: u32, b: u32, c: u32, d: u32, k: u32| -> u32 {
        ((SBOX[a as u8 as usize] as u32)
            | ((SBOX[(b >> 8) as u8 as usize] as u32) << 8)
            | ((SBOX[(c >> 16) as u8 as usize] as u32) << 16)
            | ((SBOX[(d >> 24) as u8 as usize] as u32) << 24))
            ^ k
    };
    for (b, st) in blocks.iter_mut().zip(s.iter()) {
        let [s0, s1, s2, s3] = *st;
        let t0 = f(s0, s1, s2, s3, rk[40]);
        let t1 = f(s1, s2, s3, s0, rk[41]);
        let t2 = f(s2, s3, s0, s1, rk[42]);
        let t3 = f(s3, s0, s1, s2, rk[43]);
        b[0..4].copy_from_slice(&t0.to_le_bytes());
        b[4..8].copy_from_slice(&t1.to_le_bytes());
        b[8..12].copy_from_slice(&t2.to_le_bytes());
        b[12..16].copy_from_slice(&t3.to_le_bytes());
    }
}

/// Decrypt one 16-byte block in place (software path, straightforward
/// byte-oriented implementation — decryption is never on the hot path:
/// GCM/CTR only use the forward direction).
pub fn decrypt_block_soft(key: &AesKey, block: &mut [u8; 16]) {
    let mut state = *block;
    add_round_key(&mut state, key, ROUNDS);
    for r in (1..ROUNDS).rev() {
        inv_shift_rows(&mut state);
        for b in state.iter_mut() {
            *b = INV_SBOX[*b as usize];
        }
        add_round_key(&mut state, key, r);
        inv_mix_columns(&mut state);
    }
    inv_shift_rows(&mut state);
    for b in state.iter_mut() {
        *b = INV_SBOX[*b as usize];
    }
    add_round_key(&mut state, key, 0);
    *block = state;
}

fn add_round_key(state: &mut [u8; 16], key: &AesKey, r: usize) {
    let rk = key.round_key_bytes(r);
    for i in 0..16 {
        state[i] ^= rk[i];
    }
}

fn inv_shift_rows(state: &mut [u8; 16]) {
    // Row r (bytes r, r+4, r+8, r+12) rotates right by r.
    let s = *state;
    for r in 1..4 {
        for c in 0..4 {
            state[r + 4 * ((c + r) % 4)] = s[r + 4 * c];
        }
    }
}

fn inv_mix_columns(state: &mut [u8; 16]) {
    for c in 0..4 {
        let col = [state[4 * c], state[4 * c + 1], state[4 * c + 2], state[4 * c + 3]];
        state[4 * c] =
            gf_mul(col[0], 14) ^ gf_mul(col[1], 11) ^ gf_mul(col[2], 13) ^ gf_mul(col[3], 9);
        state[4 * c + 1] =
            gf_mul(col[0], 9) ^ gf_mul(col[1], 14) ^ gf_mul(col[2], 11) ^ gf_mul(col[3], 13);
        state[4 * c + 2] =
            gf_mul(col[0], 13) ^ gf_mul(col[1], 9) ^ gf_mul(col[2], 14) ^ gf_mul(col[3], 11);
        state[4 * c + 3] =
            gf_mul(col[0], 11) ^ gf_mul(col[1], 13) ^ gf_mul(col[2], 9) ^ gf_mul(col[3], 14);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// FIPS-197 Appendix B example.
    #[test]
    fn fips197_appendix_b() {
        let key: [u8; 16] = [
            0x2b, 0x7e, 0x15, 0x16, 0x28, 0xae, 0xd2, 0xa6, 0xab, 0xf7, 0x15, 0x88, 0x09, 0xcf,
            0x4f, 0x3c,
        ];
        let mut block: [u8; 16] = [
            0x32, 0x43, 0xf6, 0xa8, 0x88, 0x5a, 0x30, 0x8d, 0x31, 0x31, 0x98, 0xa2, 0xe0, 0x37,
            0x07, 0x34,
        ];
        let k = AesKey::new(&key);
        encrypt_block_soft(&k, &mut block);
        let expect: [u8; 16] = [
            0x39, 0x25, 0x84, 0x1d, 0x02, 0xdc, 0x09, 0xfb, 0xdc, 0x11, 0x85, 0x97, 0x19, 0x6a,
            0x0b, 0x32,
        ];
        assert_eq!(block, expect);
    }

    /// FIPS-197 Appendix C.1 known-answer test.
    #[test]
    fn fips197_appendix_c1() {
        let key: [u8; 16] =
            core::array::from_fn(|i| i as u8); // 000102...0f
        let mut block: [u8; 16] = core::array::from_fn(|i| (i as u8) * 0x11); // 00112233...
        let k = AesKey::new(&key);
        encrypt_block_soft(&k, &mut block);
        let expect: [u8; 16] = [
            0x69, 0xc4, 0xe0, 0xd8, 0x6a, 0x7b, 0x04, 0x30, 0xd8, 0xcd, 0xb7, 0x80, 0x70, 0xb4,
            0xc5, 0x5a,
        ];
        assert_eq!(block, expect);
    }

    /// The interleaved N-block path is the single-block path N times.
    #[test]
    fn interleaved_blocks_match_single() {
        let k = AesKey::new(&[0x6fu8; 16]);
        let mut wide: [[u8; 16]; 4] =
            core::array::from_fn(|i| core::array::from_fn(|j| (i * 37 + j * 5) as u8));
        let mut narrow = wide;
        encrypt_blocks_soft(&k, &mut wide);
        for b in narrow.iter_mut() {
            encrypt_block_soft(&k, b);
        }
        assert_eq!(wide, narrow);
    }

    #[test]
    fn encrypt_decrypt_round_trip() {
        let key = [0xa5u8; 16];
        let k = AesKey::new(&key);
        for seed in 0u8..32 {
            let orig: [u8; 16] = core::array::from_fn(|i| seed.wrapping_mul(31).wrapping_add(i as u8));
            let mut b = orig;
            encrypt_block_soft(&k, &mut b);
            assert_ne!(b, orig);
            decrypt_block_soft(&k, &mut b);
            assert_eq!(b, orig);
        }
    }

    #[test]
    fn key_schedule_first_last_words() {
        // FIPS-197 A.1 key expansion example: last round key words.
        let key: [u8; 16] = [
            0x2b, 0x7e, 0x15, 0x16, 0x28, 0xae, 0xd2, 0xa6, 0xab, 0xf7, 0x15, 0x88, 0x09, 0xcf,
            0x4f, 0x3c,
        ];
        let k = AesKey::new(&key);
        // w43 = 0xb6630ca6 in FIPS (big-endian word); our words are LE bytes
        // of the same column, i.e. bytes b6 63 0c a6 -> LE u32 0xa60c63b6.
        assert_eq!(k.rk[43], 0xa60c63b6);
    }

    #[test]
    fn gf_mul_table_consistency() {
        // xtime agrees with gf_mul(·, 2); distributivity spot checks.
        for a in 0..=255u8 {
            assert_eq!(gf_mul(a, 2), xtime(a));
            assert_eq!(gf_mul(a, 1), a);
            assert_eq!(gf_mul(a, 3), xtime(a) ^ a);
        }
    }

    /// Cross-check the software path against the RustCrypto `aes` crate
    /// over many random-ish blocks and keys. Behind the `oracle` feature:
    /// the default build assumes no external crates (the inline FIPS-197
    /// vectors above are the always-on correctness anchor).
    #[cfg(feature = "oracle")]
    #[test]
    fn oracle_rustcrypto_aes() {
        use aes::cipher::{BlockEncrypt, KeyInit};
        let mut st = 0x12345678u64;
        let mut next = move || {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            st
        };
        for _ in 0..200 {
            let mut key = [0u8; 16];
            let mut blk = [0u8; 16];
            for i in 0..2 {
                key[8 * i..8 * i + 8].copy_from_slice(&next().to_le_bytes());
                blk[8 * i..8 * i + 8].copy_from_slice(&next().to_le_bytes());
            }
            let ours_key = AesKey::new(&key);
            let mut ours = blk;
            encrypt_block_soft(&ours_key, &mut ours);

            let oracle = aes::Aes128::new(&key.into());
            let mut theirs = aes::Block::from(blk);
            oracle.encrypt_block(&mut theirs);
            let theirs_bytes: [u8; 16] = theirs.into();
            assert_eq!(ours, theirs_bytes);
        }
    }
}
