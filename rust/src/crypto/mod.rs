//! Cryptographic substrate — everything CryptMPI needs, from scratch.
//!
//! * [`aes`] / [`aesni`] — AES-128 block cipher (portable T-tables with
//!   N-wide interleave + AES-NI).
//! * [`ghash`] / [`clmul`] — GHASH in GF(2^128) (bit-serial reference,
//!   Shoup 4-bit tables, PCLMULQDQ with 8-wide aggregated reduction).
//! * [`gcm`] — AES-128-GCM authenticated encryption (SP 800-38D) with
//!   fused one-pass seal/open kernels (two-pass kept as the reference).
//! * [`stream`] — the paper's Algorithm 1: chopped streaming AE with
//!   Tink-style subkey derivation, plus the wire header codec.
//! * [`sha256`] — SHA-256 and MGF1 (for OAEP).
//! * [`bignum`] — u64-limb big integers, Montgomery modpow, Miller-Rabin.
//! * [`rsa`] — RSA-OAEP keypairs for the `MPI_Init` key distribution.
//! * [`rand`] — ChaCha20 CSPRNG (keys/nonces/seeds) and xoshiro256**
//!   deterministic PRNG (simulation workloads only).
//! * [`wipe`] — volatile zeroization; every key-schedule type wipes its
//!   backing bytes on `Drop` (enforced by the `key-hygiene` cryptlint rule).
//!
//! Oracles: NIST/FIPS/RFC test vectors inline (always on); the RustCrypto
//! `aes`/`sha2` cross-checks behind the `oracle` feature; and the
//! independently authored JAX/Pallas GCM (via PJRT) in the integration
//! tests behind the `pjrt` feature. The default build is dependency-free.

pub mod aes;
pub mod aesni;
pub mod bignum;
pub mod clmul;
pub mod gcm;
pub mod ghash;
pub mod rand;
pub mod rsa;
pub mod sha256;
pub mod stream;
pub mod wipe;

pub use gcm::{AuthError, Gcm, NONCE_LEN, TAG_LEN};
pub use stream::{
    chop_decrypt_wire_parallel, chop_decrypt_wire_scatter, chop_decrypt_wire_scatter_parallel,
    chop_encrypt_gather_into, chop_encrypt_gather_into_parallel,
    chop_encrypt_gather_into_seeded,
    chop_encrypt_gather_into_parallel_seeded, chop_encrypt_into_parallel,
    chop_encrypt_into_parallel_seeded, chop_encrypt_into_seeded, GatherCursor, Header, Opcode,
    ScatterCursor, StreamOpener, StreamSealer, CHOP_THRESHOLD, HEADER_LEN,
};
