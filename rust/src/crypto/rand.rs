//! Randomness: a ChaCha20-based CSPRNG seeded from the OS, plus a fast
//! deterministic PRNG for simulation workloads.
//!
//! The CSPRNG feeds everything security-relevant (GCM keys, Algorithm 1
//! seeds `V`, small-message nonces, RSA prime candidates). The
//! deterministic [`SimRng`] feeds everything that must be reproducible
//! (synthetic matrices, payload patterns, benchmark workloads) and is never
//! used for key material.

use std::sync::Mutex;

/// The ChaCha20 quarter round.
#[inline(always)]
fn qr(state: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(16);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(12);
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(8);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(7);
}

/// One ChaCha20 block (RFC 8439) for key `key`, counter `ctr`, nonce `nonce`.
pub fn chacha20_block(key: &[u8; 32], ctr: u32, nonce: &[u8; 12], out: &mut [u8; 64]) {
    let mut s = [0u32; 16];
    s[0] = 0x61707865;
    s[1] = 0x3320646e;
    s[2] = 0x79622d32;
    s[3] = 0x6b206574;
    for i in 0..8 {
        s[4 + i] = u32::from_le_bytes(key[4 * i..4 * i + 4].try_into().unwrap());
    }
    s[12] = ctr;
    for i in 0..3 {
        s[13 + i] = u32::from_le_bytes(nonce[4 * i..4 * i + 4].try_into().unwrap());
    }
    let init = s;
    for _ in 0..10 {
        qr(&mut s, 0, 4, 8, 12);
        qr(&mut s, 1, 5, 9, 13);
        qr(&mut s, 2, 6, 10, 14);
        qr(&mut s, 3, 7, 11, 15);
        qr(&mut s, 0, 5, 10, 15);
        qr(&mut s, 1, 6, 11, 12);
        qr(&mut s, 2, 7, 8, 13);
        qr(&mut s, 3, 4, 9, 14);
    }
    for i in 0..16 {
        out[4 * i..4 * i + 4].copy_from_slice(&s[i].wrapping_add(init[i]).to_le_bytes());
    }
}

/// ChaCha20-based deterministic random bit generator.
pub struct ChaChaRng {
    key: [u8; 32],
    nonce: [u8; 12],
    ctr: u32,
    buf: [u8; 64],
    pos: usize,
}

impl ChaChaRng {
    pub fn from_seed(key: [u8; 32]) -> Self {
        ChaChaRng { key, nonce: [0u8; 12], ctr: 0, buf: [0u8; 64], pos: 64 }
    }

    /// Seed from the operating system (`/dev/urandom`).
    pub fn from_os() -> std::io::Result<Self> {
        use std::io::Read;
        let mut key = [0u8; 32];
        std::fs::File::open("/dev/urandom")?.read_exact(&mut key)?;
        Ok(Self::from_seed(key))
    }

    pub fn fill(&mut self, out: &mut [u8]) {
        for b in out.iter_mut() {
            if self.pos == 64 {
                chacha20_block(&self.key, self.ctr, &self.nonce, &mut self.buf);
                self.ctr = self.ctr.wrapping_add(1);
                self.pos = 0;
            }
            *b = self.buf[self.pos];
            self.pos += 1;
        }
    }

    pub fn gen<const N: usize>(&mut self) -> [u8; N] {
        let mut out = [0u8; N];
        self.fill(&mut out);
        out
    }
}

/// Process-global CSPRNG (lazily seeded from the OS).
static GLOBAL: Mutex<Option<ChaChaRng>> = Mutex::new(None);

/// Fill `out` with cryptographically secure random bytes.
pub fn secure_bytes(out: &mut [u8]) {
    let mut guard = GLOBAL.lock().unwrap();
    let rng = guard.get_or_insert_with(|| {
        ChaChaRng::from_os().expect("cannot open /dev/urandom")
    });
    rng.fill(out);
}

/// A secure random array (keys, seeds, nonces).
pub fn secure_array<const N: usize>() -> [u8; N] {
    let mut out = [0u8; N];
    secure_bytes(&mut out);
    out
}

/// xoshiro256** — fast deterministic PRNG for simulation workloads.
/// NOT for key material.
#[derive(Clone, Debug)]
pub struct SimRng {
    s: [u64; 4],
}

impl SimRng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = move || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        SimRng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        // Multiply-shift; bias negligible for simulation use.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn fill(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rest = chunks.into_remainder();
        if !rest.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rest.copy_from_slice(&b[..rest.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// RFC 8439 §2.3.2 ChaCha20 block test vector.
    #[test]
    fn rfc8439_block() {
        let key: [u8; 32] = core::array::from_fn(|i| i as u8);
        let nonce: [u8; 12] =
            [0x00, 0x00, 0x00, 0x09, 0x00, 0x00, 0x00, 0x4a, 0x00, 0x00, 0x00, 0x00];
        let mut out = [0u8; 64];
        chacha20_block(&key, 1, &nonce, &mut out);
        assert_eq!(
            &out[..16],
            &[0x10, 0xf1, 0xe7, 0xe4, 0xd1, 0x3b, 0x59, 0x15, 0x50, 0x0f, 0xdd, 0x1f, 0xa3, 0x20,
              0x71, 0xc4]
        );
        assert_eq!(out[63], 0x4e);
    }

    #[test]
    fn chacharng_deterministic_and_streamy() {
        let mut a = ChaChaRng::from_seed([7u8; 32]);
        let mut b = ChaChaRng::from_seed([7u8; 32]);
        let mut x = [0u8; 100];
        a.fill(&mut x);
        let mut y1 = [0u8; 60];
        let mut y2 = [0u8; 40];
        b.fill(&mut y1);
        b.fill(&mut y2);
        assert_eq!(&x[..60], &y1[..]);
        assert_eq!(&x[60..], &y2[..]);
        let mut c = ChaChaRng::from_seed([8u8; 32]);
        let mut z = [0u8; 100];
        c.fill(&mut z);
        assert_ne!(x, z);
    }

    #[test]
    fn secure_bytes_nonzero_and_distinct() {
        let a: [u8; 32] = secure_array();
        let b: [u8; 32] = secure_array();
        assert_ne!(a, b);
        assert_ne!(a, [0u8; 32]);
    }

    #[test]
    fn simrng_statistics_rough() {
        let mut r = SimRng::new(42);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        let mut r2 = SimRng::new(42);
        let mut r3 = SimRng::new(42);
        assert_eq!(r2.next_u64(), r3.next_u64());
    }

    /// Proposition 1 arithmetic: the collision bound q^2 / 2^129 for
    /// q = 2^28 seeds is ≤ 2^-73 — i.e. astronomically small. We check the
    /// bound expression rather than sampling 2^28 values.
    #[test]
    fn proposition1_bound() {
        let q = (1u128) << 28;
        // q^2 / 2^129 as a power of two exponent: 56 - 129 = -73.
        let log2_bound = 2.0 * (q as f64).log2() - 129.0;
        assert!(log2_bound < -70.0);
    }
}
