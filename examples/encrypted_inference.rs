//! End-to-end driver (DESIGN.md §6): encrypted distributed inference.
//!
//! Proves that all three layers compose on a real workload:
//!   * L1/L2 — the MLP block was authored in JAX (Pallas matmul inside)
//!     and AOT-lowered to `artifacts/mlp_8x128.hlo.txt`;
//!   * runtime — every "node" loads the artifact through PJRT and runs the
//!     real forward pass (no Python anywhere);
//!   * L3 — activations cross nodes through CryptMPI's encrypted
//!     point-to-point path; the driver serves batched requests over a
//!     2-stage pipeline and reports latency/throughput for the three
//!     libraries of the paper.
//!
//! ```bash
//! make artifacts && cargo run --release --example encrypted_inference
//! ```

use cryptmpi::coordinator::{run_cluster, ClusterConfig, SecurityMode};
use cryptmpi::crypto::rand::SimRng;
use cryptmpi::net::SystemProfile;
use cryptmpi::runtime::Service;

const BATCH: usize = 8;
const DIM: usize = 128;
const HIDDEN: usize = 256;
const REQUESTS: usize = 24;

fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
}

fn weights(seed: u64, n: usize, scale: f32) -> Vec<f32> {
    let mut rng = SimRng::new(seed);
    (0..n).map(|_| (rng.f64() as f32 - 0.5) * scale).collect()
}

fn serve(mode: SecurityMode, rt: Service) -> (f64, f64, Vec<f32>) {
    // 2 ranks on 2 nodes: rank 0 = pipeline stage 1, rank 1 = stage 2.
    let cfg = ClusterConfig::pingpong(SystemProfile::noleland(), mode);
    let (outs, rep) = run_cluster(&cfg, move |rank| {
        // Each stage owns one MLP block (distinct weights).
        let stage = rank.id() as u64;
        let w1 = weights(100 + stage, DIM * HIDDEN, 0.2);
        let b1 = weights(200 + stage, HIDDEN, 0.1);
        let w2 = weights(300 + stage, HIDDEN * DIM, 0.2);
        let b2 = weights(400 + stage, DIM, 0.1);
        let mut last_logits = Vec::new();
        // Virtual cost of one artifact execution on a "node" (charged as
        // compute; the real PJRT execution provides the actual numbers).
        let flop_cost_ns = (2.0 * (BATCH * DIM * HIDDEN * 2) as f64 * 0.5) as u64;
        for req in 0..REQUESTS as u64 {
            if rank.id() == 0 {
                // Batched request arrives at stage 1.
                let x = weights(1000 + req, BATCH * DIM, 1.0);
                let h = rt.mlp_forward(&x, &w1, &b1, &w2, &b2).expect("stage-1 forward");
                rank.compute_ns(flop_cost_ns);
                // Activations cross to the other node encrypted (64 KB+
                // batches would chop; this 4 KB activation uses the
                // direct-GCM small path).
                rank.send(1, req, &f32s_to_bytes(&h));
            } else {
                let act = bytes_to_f32s(&rank.recv(0, req));
                let y = rt.mlp_forward(&act, &w1, &b1, &w2, &b2).expect("stage-2 forward");
                rank.compute_ns(flop_cost_ns);
                last_logits = y;
            }
        }
        last_logits
    });
    let elapsed_s = rep.per_rank[1].elapsed_ns as f64 / 1e9;
    let latency_ms = elapsed_s * 1e3 / REQUESTS as f64;
    let throughput = (REQUESTS * BATCH) as f64 / elapsed_s;
    (latency_ms, throughput, outs[1].clone())
}

fn main() -> anyhow::Result<()> {
    let rt = Service::start(None)?;
    println!("== encrypted inference: 2-stage pipeline, batch {BATCH}, {REQUESTS} requests ==");
    let mut baseline = Vec::new();
    for mode in [SecurityMode::Unencrypted, SecurityMode::CryptMpi, SecurityMode::Naive] {
        let (lat, tput, logits) = serve(mode, rt.clone());
        if baseline.is_empty() {
            baseline = logits.clone();
        } else {
            // Correctness across modes: encryption must not change results.
            assert_eq!(logits, baseline, "mode {mode:?} changed inference output");
        }
        println!(
            "{:12}: {:7.3} ms/request  {:8.1} samples/s  (output[0..3] = {:?})",
            mode.name(),
            lat,
            tput,
            &logits[..3]
        );
    }
    println!("\nall modes produce identical logits; e2e stack (Pallas→HLO→PJRT→CryptMPI) OK");
    Ok(())
}
