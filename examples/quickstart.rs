//! Quickstart: bring up a 4-rank encrypted cluster, run the full RSA-OAEP
//! key distribution, exchange encrypted messages, and demonstrate tamper
//! detection.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cryptmpi::coordinator::{run_cluster, ClusterConfig, KeyDistMode, SecurityMode};
use cryptmpi::crypto::rand::SimRng;
use cryptmpi::crypto::{Gcm, Header, Opcode, StreamSealer};
use cryptmpi::net::SystemProfile;

fn main() {
    // 4 ranks on 2 nodes of the simulated Noleland cluster; keys are
    // distributed with the paper's RSA-OAEP protocol at init.
    let mut cfg = ClusterConfig::new(4, 2, SystemProfile::noleland(), SecurityMode::CryptMpi);
    cfg.keydist = KeyDistMode::RsaOaep { bits: 1024 };

    println!("== CryptMPI quickstart: 4 ranks / 2 nodes, RSA-OAEP key distribution ==");
    let (_, report) = run_cluster(&cfg, |rank| {
        let me = rank.id();
        // A large (2 MB) message crosses nodes: (k,t)-chopping kicks in.
        let mut payload = vec![0u8; 2 << 20];
        SimRng::new(7).fill(&mut payload);
        if me == 0 {
            rank.send(2, 42, &payload); // rank 2 lives on the other node
            println!("rank 0: sent 2 MiB encrypted ((k,t)-chopped) to rank 2");
        } else if me == 2 {
            let got = rank.recv(0, 42);
            assert_eq!(got, payload);
            println!(
                "rank 2: received + authenticated 2 MiB (crypto time {:.1} us)",
                rank.stats().crypto_ns as f64 / 1e3
            );
        }
        // Small message: direct GCM path under K2.
        if me == 1 {
            rank.send(3, 43, b"small message -> direct GCM under K2");
        } else if me == 3 {
            let got = rank.recv(1, 43);
            println!("rank 3: small-path message: {:?}", String::from_utf8_lossy(&got));
        }
        rank.barrier();
    });
    for r in &report.per_rank {
        println!(
            "rank {}: T_e={:.3} ms, inter-node comm {:.3} ms, crypto {:.3} ms",
            r.rank,
            r.elapsed_ns as f64 / 1e6,
            r.stats.inter_ns as f64 / 1e6,
            r.stats.crypto_ns as f64 / 1e6,
        );
    }

    // Tamper-detection demo on the wire format itself.
    println!("\n== tamper detection ==");
    let k1 = Gcm::new(&[7u8; 16]);
    let msg = vec![0xabu8; 256 * 1024];
    let sealer = StreamSealer::new(&k1, msg.len(), 8);
    let mut seg1 = msg[sealer.segment_range(1)].to_vec();
    let tag = sealer.seal_segment(1, &mut seg1);
    println!("sealed segment 1 of {} ({} bytes)", sealer.num_segments(), seg1.len());

    let opener = cryptmpi::crypto::StreamOpener::new(&k1, sealer.header()).unwrap();
    let mut ok = seg1.clone();
    assert!(opener.open_segment(1, &mut ok, &tag).is_ok());
    println!("intact segment: authenticated OK");

    let mut flipped = seg1.clone();
    flipped[1000] ^= 1;
    assert!(opener.open_segment(1, &mut flipped, &tag).is_err());
    println!("bit-flipped segment: REJECTED");

    let mut wrong_pos = seg1.clone();
    assert!(opener.open_segment(2, &mut wrong_pos, &tag).is_err());
    println!("reordered segment (position 1 presented as 2): REJECTED");

    let mut hdr = Header::decode(&sealer.header().encode()).unwrap();
    hdr.seed[0] ^= 1;
    let bad_opener = cryptmpi::crypto::StreamOpener::new(&k1, &hdr).unwrap();
    let mut replay = seg1;
    assert!(bad_opener.open_segment(1, &mut replay, &tag).is_err());
    println!("tampered header seed: REJECTED");
    assert_eq!(hdr.opcode, Opcode::Chopped);
    println!("\nquickstart OK");
}
