//! Stencil application with *real* PJRT compute: each rank holds a
//! 128×128 f32 state tile, advances it every round through the AOT-lowered
//! Pallas matmul artifact (`stencil_128.hlo.txt`), and exchanges encrypted
//! halos with its grid neighbours.
//!
//! ```bash
//! make artifacts && cargo run --release --example stencil_app -- [--mode cryptmpi]
//! ```

use cryptmpi::coordinator::{run_cluster, ClusterConfig, SecurityMode};
use cryptmpi::crypto::rand::SimRng;
use cryptmpi::net::SystemProfile;
use cryptmpi::runtime::Service;

const N: usize = 128;
const ROUNDS: usize = 10;

fn main() -> anyhow::Result<()> {
    let mode = std::env::args()
        .skip_while(|a| a != "--mode")
        .nth(1)
        .and_then(|s| SecurityMode::by_name(&s))
        .unwrap_or(SecurityMode::CryptMpi);
    let rt = Service::start(None)?;

    // 2×2 grid on 4 nodes — all halos are inter-node (encrypted).
    let cfg = ClusterConfig::new(4, 1, SystemProfile::noleland(), mode);
    println!("== 2D stencil with PJRT compute, mode={} ==", mode.name());
    let (sums, rep) = run_cluster(&cfg, move |rank| {
        let me = rank.id();
        let (row, col) = (me / 2, me % 2);
        let mut nbrs = Vec::new();
        if row == 0 { nbrs.push(me + 2) } else { nbrs.push(me - 2) };
        if col == 0 { nbrs.push(me + 1) } else { nbrs.push(me - 1) };

        let mut rng = SimRng::new(me as u64 + 1);
        let mut state: Vec<f32> = (0..N * N).map(|_| rng.f64() as f32 - 0.5).collect();
        let w: Vec<f32> = {
            let mut r = SimRng::new(99); // shared weights
            (0..N * N).map(|_| (r.f64() as f32 - 0.5) * 0.15).collect()
        };

        for round in 0..ROUNDS as u64 {
            // Real compute through the PJRT artifact (tanh(state @ w)).
            state = rt.stencil_step(&state, &w).expect("stencil artifact");
            // Charge virtual time for the matmul (2·N³ flops at ~2 GF/s).
            rank.compute_ns((2.0 * (N * N * N) as f64 * 0.5) as u64);
            // Exchange halo rows (encrypted when inter-node).
            let halo: Vec<u8> =
                state[..N].iter().flat_map(|x| x.to_le_bytes()).collect();
            let sends: Vec<_> = nbrs.iter().map(|&nb| rank.isend(nb, round, &halo)).collect();
            let recvs: Vec<_> = nbrs.iter().map(|&nb| rank.irecv(nb, round)).collect();
            let halos = rank.waitall_recv(recvs);
            rank.waitall_send(sends);
            // Fold received halos into the boundary (simple average).
            for h in halos {
                for (i, c) in h.chunks_exact(4).enumerate().take(N) {
                    state[i] = 0.5 * (state[i] + f32::from_le_bytes(c.try_into().unwrap()));
                }
            }
        }
        state.iter().map(|x| *x as f64).sum::<f64>()
    });

    for (r, s) in rep.per_rank.iter().zip(&sums) {
        println!(
            "rank {}: state-sum {:+.4}, T_e={:.3} ms (comm {:.3} ms, crypto {:.3} ms)",
            r.rank,
            s,
            r.elapsed_ns as f64 / 1e6,
            r.stats.total_comm_ns() as f64 / 1e6,
            r.stats.crypto_ns as f64 / 1e6,
        );
    }
    println!("stencil_app OK ({} rounds of real PJRT compute + encrypted halos)", ROUNDS);
    Ok(())
}
